"""Chaos harness: deterministic fault injection + the metamorphic contract.

Headline property (ISSUE 2): shard membership is semantics-invisible, so ANY
chaos schedule — volunteer churn, forced expiry, live shard add/remove — must
produce the IDENTICAL SimResult on a K-shard federation as on one QueueServer,
in both event and poll modes. The ChaosSimulator additionally asserts, around
every membership change, that migration preserved a full census of live queue
state (remove_shard loses zero messages) and every queue's structural
invariants.
"""
from __future__ import annotations

import pytest

from repro.core.chaos import (ChaosEvent, ChaosSchedule, ChaosSimulator,
                              churn_schedule, federation_census,
                              metamorphic_check, mixed_schedule, reshard_schedule,
                              run_chaos, _smoke_cost, _smoke_problem,
                              _smoke_specs)
from repro.core.simulator import Simulator, SyntheticProblem, VolunteerSpec
from repro.core.transport import FaultSpec

SEEDS = range(5)

# the same workload/population/cost the CI smoke uses — imported, not copied,
# so tuning one cannot silently desynchronize the other
_problem, _specs, _cost = _smoke_problem, _smoke_specs, _smoke_cost

LEAVABLE = [s.vid for s in _specs() if s.vid.startswith("x")]

SCHEDULES = {
    "churn": lambda seed: churn_schedule(seed, leavable=LEAVABLE),
    "reshard": reshard_schedule,
    "mixed": lambda seed: mixed_schedule(seed, leavable=LEAVABLE),
}


# ---------------------------------------------------------------------------
# the metamorphic contract: 5 seeds x 3 schedule families x 2 modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["event", "poll"])
@pytest.mark.parametrize("family", sorted(SCHEDULES))
@pytest.mark.parametrize("seed", SEEDS)
def test_sharded_simresult_bitmatches_single_server(seed, family, mode):
    schedule = SCHEDULES[family](seed)
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=3)
    assert single == sharded                 # full dataclass: timeline floats,
    assert single.final_version == 5         # event counts, byte counts, all
    assert single.mode == mode


@pytest.mark.parametrize("mode", ["event", "poll"])
@pytest.mark.parametrize("seed", SEEDS)
def test_metamorphic_holds_with_live_expiries(seed, mode):
    """Tight visibility: leases expire mid-task, so shard migrations carry
    in-flight messages WITH pending deadlines — the deadline index must be
    rebuilt at the destination or expiry would silently stop."""
    schedule = mixed_schedule(seed, leavable=LEAVABLE)
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=4,
                                        visibility_timeout=0.6)
    assert single == sharded
    assert single.final_version == 5
    assert single.requeues > 0 and single.expire_scans > 0


def test_chaos_replay_is_bit_identical():
    """Same (seed, schedule, specs) -> the same SimResult, twice over: the
    harness has no hidden entropy, so any failure replays from its seed."""
    schedule = mixed_schedule(3, leavable=LEAVABLE)
    a = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=3,
                  cost=_cost())
    b = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=3,
                  cost=_cost())
    assert a == b
    assert a.timeline == b.timeline and a.makespan == b.makespan


def test_scripted_schedule_joins_and_resharding():
    """Hand-written script: a mid-run join picks up work; shard membership
    shrinks to 1 and grows again; the run completes with every task done."""
    script = ChaosSchedule([
        ChaosEvent(1.0, "add_shard"),
        ChaosEvent(2.0, "join", vid="late", speed=2.5),
        ChaosEvent(3.0, "remove_shard", shard=0),
        ChaosEvent(4.0, "remove_shard", shard=1),
        ChaosEvent(5.0, "remove_shard", shard=0),   # down to a single shard
        ChaosEvent(6.0, "add_shard"),
        ChaosEvent(8.0, "leave", vid="x00"),
        ChaosEvent(9.0, "expire"),
    ], label="scripted")
    single, sharded = (
        run_chaos(_problem(), _specs(), script, mode="event", n_shards=k,
                  cost=_cost()) for k in (1, 3))
    assert single == sharded
    n_tasks = 5 * (6 + 1)
    assert sum(single.tasks_by_worker.values()) == n_tasks
    assert single.tasks_by_worker.get("late", 0) > 0    # the join contributed


def test_remove_shard_conservation_census():
    """Census-level zero-loss check, visible from the test (the simulator also
    asserts it internally on every membership change)."""
    problem, specs = _problem(), _specs()
    schedule = ChaosSchedule([ChaosEvent(2.0, "remove_shard", shard=1)])
    sim = ChaosSimulator(problem, specs, schedule=schedule, mode="event",
                         n_shards=4, cost=_cost(), visibility_timeout=1e9)
    # run manually up to just before the chaos event, snapshot, then finish
    before = {}
    orig = sim._chaos

    def instrumented(ev):
        before.update(federation_census(sim.qs))
        n_shards_before = len(sim.qs.shards)
        orig(ev)
        assert len(sim.qs.shards) == n_shards_before - 1
        after = federation_census(sim.qs)
        assert after == before               # zero messages lost or mutated
        assert sim.queues_migrated > 0       # ...and something actually moved

    sim._chaos = instrumented
    res = sim.run()
    assert res.final_version == 5
    assert before, "chaos event never fired"


# ---------------------------------------------------------------------------
# transport faults (ISSUE 3): wire serialization + lossy notification delivery
# ---------------------------------------------------------------------------

_FAULTS = FaultSpec(drop_version_ready=0.3, duplicate=0.2, delay=0.15,
                    delay_dt=0.4, max_faults=2)


@pytest.mark.parametrize("family", sorted(SCHEDULES))
@pytest.mark.parametrize("seed", SEEDS)
def test_metamorphic_holds_over_wire_with_message_faults(seed, family):
    """Every protocol message round-trips through bytes AND seeded
    notification faults (dropped VersionReady fires, duplicated/delayed
    wakes) hit both sides of the single-vs-sharded pair identically: the
    SimResults must still bit-match and the run must still finish — lost
    fires are recovered by the visibility-timeout/lease-expiry path."""
    schedule = SCHEDULES[family](seed)
    single, sharded = metamorphic_check(schedule, mode="event", n_shards=3,
                                        transport="wire", faults=_FAULTS,
                                        fault_seed=seed,
                                        visibility_timeout=2.0)
    assert single == sharded
    assert single.final_version == 5
    assert single.wire_bytes > 0          # traffic was actually measured


def test_dropped_version_ready_recovered_by_lease_expiry():
    """ROADMAP PR-2 "next rung", pinned down: the FIRST VersionReady delivery
    is dropped (its volunteer goes comatose holding a leased map task), the
    client-side watchdog is explicitly OFF, and the run must still complete
    because the visibility timeout requeues the abandoned task to an idle
    volunteer. The recovery is purely server-side lease expiry."""
    problem = SyntheticProblem(n_versions=2, n_mb=2, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=8.0e8,
                               reduce_flops=2.0e7)
    # 8 volunteers > 6 total tasks: the surplus idle-subscribe on the task
    # queue from t=0, so the expiry requeue always finds a live waiter. (With
    # tasks >= volunteers every volunteer parks on a future-version
    # dependency and only the client-side watchdog could recover — the
    # wire+faults metamorphic tests above exercise that path.)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.8 + 0.05 * i)
             for i in range(8)]
    sim = Simulator(problem, specs, cost=_cost_tight(), mode="event",
                    visibility_timeout=3.0,
                    transport="wire",
                    faults=FaultSpec(drop_version_ready=1.0, max_faults=1),
                    watchdog=False)
    res = sim.run()
    assert sim.port.faults["drop"] == 1   # exactly one watch fire was lost
    assert res.final_version == 2         # ...and every version committed
    assert sim.expired >= 1               # via an actual lease expiry
    assert res.requeues >= 1
    # at-least-once: the abandoned task was redone (possibly alongside other
    # expiry-driven re-executions); exactly-once is per VERSION, not per task
    assert sum(res.tasks_by_worker.values()) >= 2 * 3
    # control: same run, no faults -> completes with no expiries at all
    ctl = Simulator(problem, specs, cost=_cost_tight(), mode="event",
                    visibility_timeout=3.0, transport="wire")
    ctl_res = ctl.run()
    assert ctl_res.final_version == 2
    assert ctl.expired == 0               # fault-free: no expiry needed
    assert sum(ctl_res.tasks_by_worker.values()) == 2 * 3


def _cost_tight():
    from repro.core.simulator import CostModel
    return CostModel(flops_per_sec=2.0e9, latency=0.020, bandwidth=12.5e6,
                     poll_interval=0.200, cache_bytes=1e15)


def test_dropped_queue_wake_recovered_by_idle_watchdog():
    """Idle-queue waits have no lease to expire, so a dropped Wake needs the
    client-side re-check fallback (armed automatically under faults): the
    run must still commit every version (tasks are at-least-once)."""
    problem = SyntheticProblem(n_versions=3, n_mb=2, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=8.0e8,
                               reduce_flops=2.0e7)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.9 + 0.05 * i)
             for i in range(12)]
    sim = Simulator(problem, specs, cost=_cost_tight(), mode="event",
                    visibility_timeout=1.5, transport="wire",
                    faults=FaultSpec(drop_wake=1.0, max_faults=1))
    res = sim.run()
    assert sim.port.faults["drop"] == 1
    assert res.final_version == 3
    assert sum(res.tasks_by_worker.values()) >= 3 * 3


def test_fault_injection_replays_bit_identically():
    """Same (schedule, fault seed) -> identical SimResult, faults included:
    chaos failures under lossy delivery replay from their seeds too."""
    schedule = mixed_schedule(2, leavable=LEAVABLE)
    runs = [run_chaos(_problem(), _specs(), schedule, mode="event",
                      n_shards=3, cost=_cost(), transport="wire",
                      faults=_FAULTS, fault_seed=11,
                      visibility_timeout=2.0) for _ in range(2)]
    assert runs[0] == runs[1]


def test_leave_of_lease_holder_requeues_and_run_completes():
    """A chaos leave of a volunteer mid-task behaves like closing the tab:
    its leases requeue at leave time and the survivors finish everything."""
    schedule = ChaosSchedule([ChaosEvent(0.7, "leave", vid="x00"),
                              ChaosEvent(0.8, "leave", vid="x01")])
    res = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=2,
                    cost=_cost())
    assert res.final_version == 5
    assert res.requeues >= 1                 # the dropped leases came back
    assert sum(res.tasks_by_worker.values()) == 5 * 7
