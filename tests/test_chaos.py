"""Chaos harness: deterministic fault injection + the metamorphic contract.

Headline property (ISSUE 2): shard membership is semantics-invisible, so ANY
chaos schedule — volunteer churn, forced expiry, live shard add/remove — must
produce the IDENTICAL SimResult on a K-shard federation as on one QueueServer,
in both event and poll modes. The ChaosSimulator additionally asserts, around
every membership change, that migration preserved a full census of live queue
state (remove_shard loses zero messages) and every queue's structural
invariants.
"""
from __future__ import annotations

import pytest

from repro.core.chaos import (ChaosEvent, ChaosSchedule, ChaosSimulator,
                              churn_schedule, federation_census,
                              metamorphic_check, mixed_schedule, reshard_schedule,
                              run_chaos, _smoke_cost, _smoke_problem,
                              _smoke_specs)

SEEDS = range(5)

# the same workload/population/cost the CI smoke uses — imported, not copied,
# so tuning one cannot silently desynchronize the other
_problem, _specs, _cost = _smoke_problem, _smoke_specs, _smoke_cost

LEAVABLE = [s.vid for s in _specs() if s.vid.startswith("x")]

SCHEDULES = {
    "churn": lambda seed: churn_schedule(seed, leavable=LEAVABLE),
    "reshard": reshard_schedule,
    "mixed": lambda seed: mixed_schedule(seed, leavable=LEAVABLE),
}


# ---------------------------------------------------------------------------
# the metamorphic contract: 5 seeds x 3 schedule families x 2 modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["event", "poll"])
@pytest.mark.parametrize("family", sorted(SCHEDULES))
@pytest.mark.parametrize("seed", SEEDS)
def test_sharded_simresult_bitmatches_single_server(seed, family, mode):
    schedule = SCHEDULES[family](seed)
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=3)
    assert single == sharded                 # full dataclass: timeline floats,
    assert single.final_version == 5         # event counts, byte counts, all
    assert single.mode == mode


@pytest.mark.parametrize("mode", ["event", "poll"])
@pytest.mark.parametrize("seed", SEEDS)
def test_metamorphic_holds_with_live_expiries(seed, mode):
    """Tight visibility: leases expire mid-task, so shard migrations carry
    in-flight messages WITH pending deadlines — the deadline index must be
    rebuilt at the destination or expiry would silently stop."""
    schedule = mixed_schedule(seed, leavable=LEAVABLE)
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=4,
                                        visibility_timeout=0.6)
    assert single == sharded
    assert single.final_version == 5
    assert single.requeues > 0 and single.expire_scans > 0


def test_chaos_replay_is_bit_identical():
    """Same (seed, schedule, specs) -> the same SimResult, twice over: the
    harness has no hidden entropy, so any failure replays from its seed."""
    schedule = mixed_schedule(3, leavable=LEAVABLE)
    a = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=3,
                  cost=_cost())
    b = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=3,
                  cost=_cost())
    assert a == b
    assert a.timeline == b.timeline and a.makespan == b.makespan


def test_scripted_schedule_joins_and_resharding():
    """Hand-written script: a mid-run join picks up work; shard membership
    shrinks to 1 and grows again; the run completes with every task done."""
    script = ChaosSchedule([
        ChaosEvent(1.0, "add_shard"),
        ChaosEvent(2.0, "join", vid="late", speed=2.5),
        ChaosEvent(3.0, "remove_shard", shard=0),
        ChaosEvent(4.0, "remove_shard", shard=1),
        ChaosEvent(5.0, "remove_shard", shard=0),   # down to a single shard
        ChaosEvent(6.0, "add_shard"),
        ChaosEvent(8.0, "leave", vid="x00"),
        ChaosEvent(9.0, "expire"),
    ], label="scripted")
    single, sharded = (
        run_chaos(_problem(), _specs(), script, mode="event", n_shards=k,
                  cost=_cost()) for k in (1, 3))
    assert single == sharded
    n_tasks = 5 * (6 + 1)
    assert sum(single.tasks_by_worker.values()) == n_tasks
    assert single.tasks_by_worker.get("late", 0) > 0    # the join contributed


def test_remove_shard_conservation_census():
    """Census-level zero-loss check, visible from the test (the simulator also
    asserts it internally on every membership change)."""
    problem, specs = _problem(), _specs()
    schedule = ChaosSchedule([ChaosEvent(2.0, "remove_shard", shard=1)])
    sim = ChaosSimulator(problem, specs, schedule=schedule, mode="event",
                         n_shards=4, cost=_cost(), visibility_timeout=1e9)
    # run manually up to just before the chaos event, snapshot, then finish
    before = {}
    orig = sim._chaos

    def instrumented(ev):
        before.update(federation_census(sim.qs))
        n_shards_before = len(sim.qs.shards)
        orig(ev)
        assert len(sim.qs.shards) == n_shards_before - 1
        after = federation_census(sim.qs)
        assert after == before               # zero messages lost or mutated
        assert sim.queues_migrated > 0       # ...and something actually moved

    sim._chaos = instrumented
    res = sim.run()
    assert res.final_version == 5
    assert before, "chaos event never fired"


def test_leave_of_lease_holder_requeues_and_run_completes():
    """A chaos leave of a volunteer mid-task behaves like closing the tab:
    its leases requeue at leave time and the survivors finish everything."""
    schedule = ChaosSchedule([ChaosEvent(0.7, "leave", vid="x00"),
                              ChaosEvent(0.8, "leave", vid="x01")])
    res = run_chaos(_problem(), _specs(), schedule, mode="event", n_shards=2,
                    cost=_cost())
    assert res.final_version == 5
    assert res.requeues >= 1                 # the dropped leases came back
    assert sum(res.tasks_by_worker.values()) == 5 * 7
