"""Sans-IO protocol layer: message serialization, endpoint dispatch,
VolunteerSession behavior, and the transport contracts.

Satellite contract (ISSUE 3): EVERY protocol message plus MapTask /
ReduceTask / GradResult round-trips through canonical bytes and compares
equal — including through the stdlib-zlib fallback codec path — so the wire
transport can never silently diverge from the in-process one.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import protocol as P
from repro.core.dataserver import DataServer
from repro.core.gateway import run_volunteer
from repro.core.initiator import enqueue_problem
from repro.core.queue import QueueServer
from repro.core.simulator import SyntheticProblem
from repro.core.tasks import DeltaResult, GradResult, MapTask, ReduceTask
from repro.core.transport import (FaultSpec, FaultyTransport,
                                  InProcessTransport, WireTransport)

# one representative instance of every message type (field values chosen to
# exercise ints, floats, None, bools, strs)
MESSAGES = [
    P.Hello("w0"),
    P.LeaseReq("initial", "w0", 12.5),
    P.LeaseReq("initial", "w0", 0.0, timeout=30.0),
    P.Ack("initial", 7),
    P.Nack("map-results:v3", 9, front=False),
    P.ExtendLease("initial", 4, 12.0),
    P.ExtendLease("initial", 5, 0.0, timeout=30.0),
    P.PublishResult("map-results:v2", GradResult(2, 5, None, 1024, 0.25, "w1")),
    P.FetchModel(4, nbytes=2048),
    P.PublishModel(5, "v5", nbytes=4096),
    P.GcModels(keep_last=3),
    P.WatchVersion(6, "w2"),
    P.SubscribeQueue("initial", "w0", kind="publish"),
    P.KickQueue("initial"),
    P.DropConsumer("w3"),
    P.DepthReq("map-results:v0"),
    P.DrainedReq("initial"),
    P.LatestReq(),
    P.SubmitUpdate("initial", 11,
                   GradResult(3, 1, None, 512, 0.5, "w4", computed_at=3)),
    P.SubmitUpdate("initial", 12,
                   DeltaResult(2, 5, None, 256, 0.1, "w5", n_steps=4,
                               weight=0.5)),
    P.UpdateCommitted(7),
    P.UpdateRejected(6),
    P.Bye("w0"),
    P.LeaseGrant(3, MapTask(1, 0, 1, 2, 8)),
    P.LeaseGrant(4, ReduceTask(1, 0, 1, 16)),
    P.LeaseEmpty(),
    P.Ok(),
    P.Ok(True),
    P.Ok(17),
    P.ModelBlob(2, True, "v2"),
    P.ModelBlob(3, False),
    P.LatestVersion(9),
    P.Wake("initial", "any"),
    P.Wake("map-results:v1", "publish"),
    P.VersionReady(4),
    P.ExpireAll(37.5),
    P.Forward(3, "1", P.LeaseReq("initial", "w6", 2.0)),
    P.Forward(4, "0", P.SubscribeQueue("initial", "w6", kind="any")),
    P.ForwardReply(3, P.LeaseGrant(8, MapTask(2, 1, 2, 4, 8))),
    P.ForwardNotify("w6", P.Wake("initial", "any")),
]


def test_message_registry_is_complete():
    """Every declared message type appears in MESSAGES (so a new message
    cannot dodge the round-trip contract below)."""
    covered = {type(m) for m in MESSAGES}
    declared = set(P.REQUEST_TYPES) | set(P.REPLY_TYPES) | \
        set(P.NOTIFICATION_TYPES)
    assert declared <= covered, declared - covered


@pytest.mark.parametrize("codec", [None, "zlib"])
@pytest.mark.parametrize("msg", MESSAGES, ids=lambda m: type(m).__name__)
def test_every_message_roundtrips_bytes(msg, codec):
    data = P.encode_message(msg, codec=codec)
    assert isinstance(data, bytes)
    # codec header byte from checkpoint.serialize: R raw, D zlib/deflate
    assert data[:1] == (b"R" if codec is None else b"D")
    back = P.decode_message(data)
    assert type(back) is type(msg)
    assert back == msg


@pytest.mark.parametrize("codec", [None, "zlib"])
def test_tasks_roundtrip_bytes(codec):
    for task in (MapTask(3, 0, 3, 7, 8), ReduceTask(3, 0, 3, 16),
                 GradResult(3, 7, None, 512, 1.5, "w9")):
        assert P.decode_message(P.encode_message(task, codec=codec)) == task


@pytest.mark.parametrize("codec", [None, "zlib"])
def test_gradresult_with_array_payload_roundtrips(codec):
    """A real gradient pytree (nested dicts of float32 arrays) survives the
    bytes round-trip bit-exactly inside its PublishResult envelope."""
    rng = np.random.default_rng(0)
    payload = {"lstm": {"wx": rng.standard_normal((8, 16)).astype(np.float32),
                        "b": rng.standard_normal((16,)).astype(np.float32)},
               "head": rng.standard_normal((16, 4)).astype(np.float32)}
    msg = P.PublishResult("map-results:v1",
                          GradResult(1, 2, payload, 2048, 0.7, "w0"))
    back = P.decode_message(P.encode_message(msg, codec=codec))
    assert back.queue == msg.queue
    r = back.result
    assert (r.version, r.mb_index, r.nbytes, r.loss, r.worker) == \
        (1, 2, 2048, 0.7, "w0")
    assert np.array_equal(r.payload["lstm"]["wx"], payload["lstm"]["wx"])
    assert np.array_equal(r.payload["lstm"]["b"], payload["lstm"]["b"])
    assert np.array_equal(r.payload["head"], payload["head"])
    assert r.payload["head"].dtype == np.float32


def test_tuple_pytree_structure_survives_the_wire():
    """msgpack coerces tuples to lists; the wire codec must restore them so
    a tuple-structured blob (e.g. (params, opt_state)) or a tuple-bearing
    gradient pytree decodes with the identical tree structure."""
    params = {"w": np.ones((2, 2), np.float32)}
    opt_state = {"ms": {"w": np.zeros((2, 2), np.float32)}}
    msg = P.PublishModel(3, (params, opt_state), nbytes=64)
    back = P.decode_message(P.encode_message(msg))
    assert isinstance(back.blob, tuple) and len(back.blob) == 2
    assert np.array_equal(back.blob[0]["w"], params["w"])
    nested = P.Ok((1, (2.5, "x"), [3, (4,)]))
    assert P.decode_message(P.encode_message(nested)) == nested


def test_unknown_message_rejected_by_endpoint():
    ep = P.ServerEndpoint(QueueServer(), DataServer())
    with pytest.raises(TypeError):
        ep.handle(object())


# ---------------------------------------------------------------------------
# session + transports drive a full run
# ---------------------------------------------------------------------------

def _endpoint(n_versions=3, n_mb=4):
    problem = SyntheticProblem(n_versions=n_versions, n_mb=n_mb)
    qs, ds = QueueServer(), DataServer()
    enqueue_problem(problem, qs, ds, store_real_model=False)
    return P.ServerEndpoint(qs, ds), problem


def test_session_completes_run_over_inprocess_transport():
    ep, problem = _endpoint()
    final, tasks = run_volunteer(InProcessTransport(ep), "w0",
                                 problem.n_versions)
    assert final == problem.n_versions
    assert tasks == problem.n_versions * (4 + 1)
    assert ep.ds.latest_version == problem.n_versions


def test_session_over_wire_transport_matches_inprocess():
    """Same volunteer loop, every message through bytes: identical outcome,
    and the transport actually measured traffic."""
    ep_a, problem = _endpoint()
    ref = run_volunteer(InProcessTransport(ep_a), "w0", problem.n_versions)
    ep_b, _ = _endpoint()
    wire = WireTransport(ep_b)
    out = run_volunteer(wire, "w0", problem.n_versions)
    assert out == ref
    assert wire.bytes_sent > 0 and wire.bytes_received > 0
    assert wire.calls > 0
    assert wire.take_bytes() > 0          # tap accumulated since construction
    assert wire.take_bytes() == 0.0       # ...and take() drains it


def test_session_duplicate_task_acked_without_compute():
    """Protocol rule owned by the session: a task whose version is already
    reduced is acked as a stale duplicate and hands back no work."""
    ep, problem = _endpoint(n_versions=2, n_mb=2)
    port = InProcessTransport(ep)
    sess = P.VolunteerSession("w0", port)
    # complete the whole run with another volunteer, leaving w0 stalled
    out = sess.lease(0.0)                  # w0 leases v0 map... and stalls
    assert isinstance(out, P.TaskLeased)
    ep.qs.nack("initial", sess.tag)        # server expires w0's lease
    run_volunteer(InProcessTransport(ep), "hog", 2)
    # w0 finally advances: its task's version is long obsolete
    done = sess.advance(1.0)
    assert isinstance(done, P.TaskDone) and done.stale
    assert sess.task is None


def test_faulty_transport_is_seed_deterministic():
    spec = FaultSpec(drop_version_ready=0.5, duplicate=0.3, delay=0.2,
                     max_faults=100)

    def faults_for(seed):
        ep, problem = _endpoint()
        ft = FaultyTransport(WireTransport(ep), spec, seed=seed)
        final, _ = run_volunteer(ft, "w0", problem.n_versions)
        assert final == problem.n_versions
        return dict(ft.faults)

    assert faults_for(7) == faults_for(7)  # same seed -> same fault schedule


def test_faulty_transport_drops_version_ready():
    """drop_version_ready=1.0 suppresses watch fires entirely; requests pass
    through untouched."""
    ep, _ = _endpoint()
    seen = []
    ft = FaultyTransport(InProcessTransport(ep),
                         FaultSpec(drop_version_ready=1.0), seed=0)
    ft.set_deliver(lambda c, m: seen.append(m))
    ft.call(P.WatchVersion(0, "w0"))       # v0 committed -> fires immediately
    assert seen == []                      # ...but the delivery was dropped
    assert ft.faults["drop"] == 1
    ft.call(P.SubscribeQueue("initial", "w0"))
    assert ft.call(P.DepthReq("initial")).value > 0
    got = ft.call(P.LeaseReq("initial", "w0", 0.0))
    assert isinstance(got, P.LeaseGrant)   # request path unaffected
