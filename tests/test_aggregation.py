"""Pluggable aggregation semantics (ISSUE 4): one AggregationPolicy layer
serving sync-BSP, bounded-staleness async SGD, and local-steps model
averaging across every engine.

Contracts:
- SyncBSP is the paper baseline bit-for-bit (its schedule IS the legacy
  enqueue order; the whole existing invariance suite stays green).
- Each barrierless policy has an exact sequential reference, and the real
  Coordinator bit-matches it for ANY worker count and BOTH transports.
- Async runs are schedule-deterministic: same seed + fault schedule =>
  bit-identical SimResult across {single-server, sharded} federations —
  the chaos metamorphic contract generalized per policy.
- Staleness admission actually fires: a straggler-heavy pool under a tight
  bound discards stale gradients, requeues their tickets, and still commits
  every scheduled update.
- LeaseGrant carries staleness metadata; shard-aware placement co-locates
  map-results:* queues with the task queue without changing semantics.
"""
from __future__ import annotations

import math

import pytest

from repro.core.aggregation import (AggregationPolicy, BoundedStaleness,
                                    LocalSteps, SyncBSP, _bitmatch,
                                    make_policy)
from repro.core.chaos import (metamorphic_check, mixed_schedule, run_chaos,
                              _smoke_cost, _smoke_problem, _smoke_specs)
from repro.core.dataserver import DataServer
from repro.core.initiator import enqueue_problem
from repro.core.protocol import LeaseGrant, LeaseReq, ServerEndpoint
from repro.core.queue import QueueServer, ShardedQueueServer, colocate_results
from repro.core.simulator import (CostModel, Simulator, SyntheticProblem,
                                  VolunteerSpec)
from repro.core.tasks import (INITIAL_QUEUE, LocalTask, MapTask, ReduceTask,
                              results_queue)

LEAVABLE = [s.vid for s in _smoke_specs() if s.vid.startswith("x")]


# ---------------------------------------------------------------------------
# policy objects and schedules (no jax needed)
# ---------------------------------------------------------------------------

def test_make_policy_specs():
    assert isinstance(make_policy(None), SyncBSP)
    assert isinstance(make_policy("sync"), SyncBSP)
    assert make_policy("staleness:3") == BoundedStaleness(staleness=3)
    assert make_policy("async") == BoundedStaleness()
    assert make_policy("local:8") == LocalSteps(k=8)
    assert make_policy("local:2:0.5") == LocalSteps(k=2, weight=0.5)
    pol = LocalSteps(k=3)
    assert make_policy(pol) is pol            # instances pass through
    with pytest.raises(ValueError):
        make_policy("quorum:2")
    with pytest.raises(ValueError):
        make_policy("sync:1")


def test_policy_specs_and_descriptions():
    for pol in (SyncBSP(), BoundedStaleness(staleness=5), LocalSteps(k=2)):
        d = pol.describe()
        assert d["policy"] == pol.name and "guarantee" in d
        # spec strings round-trip through the parser
        assert make_policy(pol.spec) == pol


def test_schedules_cover_equal_gradient_work():
    """All policies schedule the same global mini-batch stream: a run of V
    BSP rounds costs V*n_mb gradient computations under every policy (local
    may pad up to k-1 at the tail)."""
    problem = SyntheticProblem(n_versions=5, n_mb=6)
    total = 5 * 6
    sync_tasks = list(SyncBSP().schedule(problem, 5))
    assert sum(1 for t in sync_tasks if t.kind == "map") == total
    assert sum(1 for t in sync_tasks if t.kind == "reduce") == 5
    async_tasks = list(BoundedStaleness().schedule(problem, 5))
    assert len(async_tasks) == total
    assert all(t.kind == "map" for t in async_tasks)
    local_tasks = list(LocalSteps(k=4).schedule(problem, 5))
    grad_work = sum(t.k for t in local_tasks)
    assert total <= grad_work < total + 4
    assert all(t.kind == "local" for t in local_tasks)
    # commit targets match schedule sizes
    assert SyncBSP().n_updates(problem, 5) == 5
    assert BoundedStaleness().n_updates(problem, 5) == total
    assert LocalSteps(k=4).n_updates(problem, 5) == math.ceil(total / 4)


def test_sync_schedule_is_the_legacy_enqueue_order():
    """Regression guard on the bit-compat claim: the default enqueue_problem
    produces exactly the old maps-then-reduce-per-version FIFO."""
    problem = SyntheticProblem(n_versions=3, n_mb=2, mini_batch_size=8)
    qs, ds = QueueServer(), DataServer()
    n = enqueue_problem(problem, qs, ds, store_real_model=False)
    assert n == 3 * (2 + 1)
    bodies = qs.queues[INITIAL_QUEUE].peek_all()
    want = []
    for v in range(3):
        e, b = problem.version_to_epoch_batch(v)
        want += [MapTask(v, e, b, mb, 8) for mb in range(2)]
        want.append(ReduceTask(v, e, b, 2))
    assert bodies == want


def test_lease_grant_carries_latest_version_metadata():
    problem = SyntheticProblem(n_versions=2, n_mb=2)
    qs, ds = QueueServer(), DataServer()
    enqueue_problem(problem, qs, ds, store_real_model=False)
    ep = ServerEndpoint(qs, ds)
    grant = ep.handle(LeaseReq(INITIAL_QUEUE, "w0", 0.0))
    assert isinstance(grant, LeaseGrant)
    assert grant.latest == ds.latest_version == 0
    ds.publish_model(1, "v1")
    grant2 = ep.handle(LeaseReq(INITIAL_QUEUE, "w1", 0.0))
    assert grant2.latest == 1


def test_grant_metadata_fast_paths_stale_duplicate_ack():
    """A task already refused by the policy at GRANT time is acked stale
    without a LatestReq round-trip (latest is monotone, so the refusal is
    permanent) — the payoff of the LeaseGrant.latest metadata."""
    from repro.core.protocol import TaskDone, VolunteerSession
    from repro.core.transport import InProcessTransport
    problem = SyntheticProblem(n_versions=2, n_mb=1)
    qs, ds = QueueServer(), DataServer()
    enqueue_problem(problem, qs, ds, store_real_model=False)
    ds.publish_model(1, "v1")                 # v0's tasks are now obsolete
    port = InProcessTransport(ServerEndpoint(qs, ds))
    sess = VolunteerSession("w0", port)
    sess.lease(0.0)
    assert sess.lease_latest == 1
    calls_before = port.calls
    out = sess.advance(0.0)
    assert isinstance(out, TaskDone) and out.stale
    assert port.calls == calls_before + 1     # the Ack alone — no LatestReq


# ---------------------------------------------------------------------------
# simulator: determinism, admission, and the generalized metamorphic contract
# ---------------------------------------------------------------------------

def _sim_cost():
    return CostModel(flops_per_sec=2.0e9, latency=0.020, bandwidth=12.5e6,
                     poll_interval=0.200, cache_bytes=1e15)


POLICIES = ["sync", "staleness:2", "local:4"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mode", ["event", "poll"])
def test_simulator_commits_full_schedule_per_policy(policy, mode):
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=8.0e8,
                               reduce_flops=2.0e7)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.7 + 0.3 * i) for i in range(4)]
    res = Simulator(problem, specs, cost=_sim_cost(), mode=mode,
                    visibility_timeout=1e9, policy=policy).run()
    expected = make_policy(policy).n_updates(problem, 4)
    assert res.final_version == expected
    assert res.policy == make_policy(policy).spec
    # every commit is one task completion under barrierless policies
    if not make_policy(policy).barrier:
        assert sum(res.tasks_by_worker.values()) == expected
        assert res.makespan > 0 and math.isfinite(res.makespan)


@pytest.mark.parametrize("policy", ["staleness:1", "staleness:3", "local:4"])
def test_async_simulation_replays_bit_identically(policy):
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=8.0e8)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.5 + 0.4 * i) for i in range(5)]
    runs = [Simulator(problem, specs, cost=_sim_cost(),
                      visibility_timeout=1e9, policy=policy).run()
            for _ in range(2)]
    assert runs[0] == runs[1]
    assert runs[0].timeline == runs[1].timeline


def test_tight_staleness_bound_discards_and_recovers():
    """A crawling straggler under staleness:0 gets its gradients refused (the
    model moved while it computed), its tickets requeue, and the run still
    commits every update — with the discards observable in the result."""
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=8.0e8)
    specs = [VolunteerSpec(f"v{i:02d}", speed=1.0 + 0.1 * i) for i in range(4)]
    specs.append(VolunteerSpec("slow", speed=0.08))
    res = Simulator(problem, specs, cost=_sim_cost(),
                    visibility_timeout=1e9, policy="staleness:0").run()
    assert res.final_version == 24
    assert res.stale_discards > 0
    assert res.requeues >= res.stale_discards   # every discard nacked a ticket
    # the discarded attempts are visible in the timeline
    assert any(ev.kind == "Compute-stale" for ev in res.timeline)


def test_unbounded_local_policy_never_discards():
    problem = SyntheticProblem(n_versions=4, n_mb=6, map_flops=8.0e8)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.5 + 0.5 * i) for i in range(4)]
    res = Simulator(problem, specs, cost=_sim_cost(),
                    visibility_timeout=1e9, policy="local:3").run()
    assert res.stale_discards == 0
    assert res.final_version == 8               # ceil(24 / 3)


@pytest.mark.parametrize("policy", ["staleness:2", "local:4"])
@pytest.mark.parametrize("seed", range(3))
def test_metamorphic_contract_holds_per_policy(seed, policy):
    """Same ChaosSchedule + seed => bit-identical SimResult across
    {single-server, sharded} — now with no reduce barrier at all."""
    schedule = mixed_schedule(seed, leavable=LEAVABLE)
    single, sharded = metamorphic_check(schedule, mode="event", n_shards=3,
                                        policy=policy)
    assert single == sharded
    expected = make_policy(policy).n_updates(_smoke_problem(), 5)
    assert single.final_version == expected


def test_metamorphic_contract_holds_per_policy_over_wire():
    from repro.core.transport import FaultSpec
    faults = FaultSpec(drop_wake=0.2, duplicate=0.2, delay=0.15, delay_dt=0.4,
                       max_faults=2)
    schedule = mixed_schedule(1, leavable=LEAVABLE)
    single, sharded = metamorphic_check(schedule, mode="event", n_shards=3,
                                        policy="staleness:2",
                                        transport="wire", faults=faults,
                                        fault_seed=7, visibility_timeout=2.0)
    assert single == sharded
    assert single.wire_bytes > 0
    assert single.final_version >= 30           # expiry duplicates may overshoot


# ---------------------------------------------------------------------------
# shard-aware placement of map-results:v* queues (open ROADMAP rung)
# ---------------------------------------------------------------------------

def test_colocated_placement_routes_results_with_task_queue():
    fed = ShardedQueueServer(5, placement=colocate_results)
    home = fed.shard_of(INITIAL_QUEUE)
    for v in range(40):
        assert fed.shard_of(results_queue(v)) == home
    # unrelated queues still spread over the ring
    others = {fed.shard_of(f"queue-{i}") for i in range(64)}
    assert len(others) > 1


def test_colocated_placement_survives_membership_changes():
    """Placement keys ride through add/remove_shard migrations: results
    queues always land wherever the task queue lands."""
    fed = ShardedQueueServer(3, placement=colocate_results)
    fed.publish(INITIAL_QUEUE, "t0")
    for v in range(6):
        fed.publish(results_queue(v), f"r{v}")
    for _ in range(2):
        fed.add_shard()
    fed.remove_shard(0)
    home = fed.shard_of(INITIAL_QUEUE)
    shard = fed.shards[home]
    for v in range(6):
        assert fed.shard_of(results_queue(v)) == home
        assert results_queue(v) in shard.queues
    assert INITIAL_QUEUE in shard.queues


@pytest.mark.parametrize("mode", ["event", "poll"])
def test_chaos_bitmatch_holds_with_colocated_placement(mode):
    """The chaos contract with the placement rule active on the sharded side:
    placement changes WHERE queues live, never what the run computes."""
    schedule = mixed_schedule(2, leavable=LEAVABLE)
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=3,
                                        placement=colocate_results)
    assert single == sharded
    assert single.final_version == 5


def test_reduce_barrier_touches_one_shard_under_colocation():
    """The point of the placement rule: with colocation, every queue a reduce
    barrier touches (task queue + its version's results queue) lives on ONE
    shard for the whole run."""
    problem = _smoke_problem()
    res = run_chaos(problem, _smoke_specs(),
                    mixed_schedule(0, leavable=LEAVABLE),
                    mode="event", n_shards=3, cost=_smoke_cost(),
                    placement=colocate_results)
    assert res.final_version == 5


# ---------------------------------------------------------------------------
# real engine: Coordinator bit-matches each policy's sequential reference
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    jax = pytest.importorskip("jax")
    from repro.configs.paper_lstm import TrainParams
    from repro.core.mapreduce import TrainingProblem
    from repro.data.text import synthetic_corpus
    tp = TrainParams(batch_size=16, examples_per_epoch=64, num_epochs=1,
                     sample_len=20, mini_batch_size=4,
                     mini_batches_to_accumulate=4)
    return TrainingProblem.paper_problem(corpus=synthetic_corpus(6000), tp=tp)




@pytest.mark.parametrize("transport", ["inproc", "wire"])
@pytest.mark.parametrize("k", [1, 3])
def test_coordinator_async_bitmatches_sequential_async(problem, k, transport):
    """The Coordinator's round-robin scheduler serializes barrierless
    tickets, so EVERY worker count must reproduce the 1-worker async SGD
    stream exactly — the async analogue of the paper's Table-4 claim."""
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import sequential_async
    seq_params, _, seq_losses = sequential_async(problem)
    res = Coordinator(problem, n_workers=k, policy="staleness:2",
                      transport=transport).run()
    assert res.final_version == 16              # 4 versions x 4 mini-batches
    assert _bitmatch(res.params, seq_params)
    assert res.losses == pytest.approx(seq_losses)
    assert res.policy == "staleness:2"


@pytest.mark.parametrize("k", [1, 2])
def test_coordinator_local_steps_bitmatches_sequential_local(problem, k):
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import sequential_local
    seq_params, _, _ = sequential_local(problem, k=4)
    res = Coordinator(problem, n_workers=k, policy="local:4").run()
    assert res.final_version == 4               # ceil(16 / 4)
    assert _bitmatch(res.params, seq_params)


def test_coordinator_async_survives_churn(problem):
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import sequential_async
    seq_params, _, _ = sequential_async(problem)
    churn = [(3, "leave", "w0"), (7, "join", "w9")]
    res = Coordinator(problem, n_workers=3, policy="staleness:2",
                      churn=churn).run()
    assert res.final_version == 16
    assert _bitmatch(res.params, seq_params)


def test_coordinator_sync_policy_explicit_is_default(problem):
    """policy='sync' is the default policy object — same schedule, same
    commits, same result as passing nothing."""
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import sequential_accumulated
    seq_params = sequential_accumulated(problem)[0]
    res = Coordinator(problem, n_workers=2, policy="sync").run()
    assert _bitmatch(res.params, seq_params)
    assert res.policy == "sync"
