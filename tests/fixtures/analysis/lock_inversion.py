"""LOCK-ORDER fixture: two locks taken in both orders on different code
paths — the classic two-thread deadlock. The static pass must find the
cycle in this file's AST; the runtime test swaps the two attributes for
``MonitoredLock``s and must see the inversion when both paths run."""
import threading


class Inverted:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.events = []

    def forward(self):
        with self._a:
            with self._b:
                self.events.append("forward")

    def backward(self):
        with self._b:
            with self._a:
                self.events.append("backward")
