"""REPRO-EXCEPT fixture: swallowed errors — in protocol dispatch these turn
bugs into silent hangs (a reply never sent, a lease never requeued)."""


def bare(handler, msg):
    try:
        return handler(msg)
    except:                                  # REPRO-EXCEPT fires here
        return None


def swallowed(handler, msg):
    try:
        return handler(msg)
    except Exception:                        # and here: Exception + lone pass
        pass


def handled_is_fine(handler, msg, log):
    try:
        return handler(msg)
    except ValueError as e:                  # named + handled: not flagged
        log.append(e)
        raise
