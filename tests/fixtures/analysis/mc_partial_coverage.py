"""Seeded fixture: a model-checker coverage map with holes.

A copy of ``repro.analysis.mc.COVERED_MESSAGES`` with three entries broken
in the three ways SCHEMA-MC must catch — ``LeaseReq`` deleted outright,
``Wake`` mapped to an empty string, ``SubmitUpdate`` mapped to whitespace —
while everything else stays covered, so the check must flag exactly those
three and stay silent on the rest.
"""
from repro.analysis.mc import COVERED_MESSAGES

COVERED = dict(COVERED_MESSAGES)
del COVERED["LeaseReq"]
COVERED["Wake"] = ""
COVERED["SubmitUpdate"] = "   "

MISSING = ("LeaseReq", "SubmitUpdate", "Wake")
