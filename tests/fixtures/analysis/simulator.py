"""REPRO-LAYER fixture: an "engine" (stem ``simulator``) driving the
consumer protocol directly on its servers instead of going through
VolunteerSession/ServerEndpoint."""


class BadEngine:
    def __init__(self, qs, ds):
        self.qs = qs
        self.ds = ds

    def steal_a_task(self, vid: str):
        return self.qs.lease("initial", vid, 0.0)    # REPRO-LAYER fires here

    def finish_behind_the_sessions_back(self, tag: int):
        self.qs.ack("initial", tag)                  # and here
        self.ds.publish_model(1, "v1")               # and here

    def depth_is_fine(self) -> int:
        # pure reads are the owner's business: not flagged
        return self.qs.depth("initial")
