"""REPRO-TIME fixture: wall-clock reads outside queue.py's clock classes.
Every flagged line would split the lease-time authority in a real engine."""
import time
from time import monotonic as mono


def stamp_deadline(timeout: float) -> float:
    return time.monotonic() + timeout        # REPRO-TIME fires here


def wall_now() -> float:
    return time.time()                       # and here


def aliased() -> float:
    return mono()                            # and via from-import alias


class NotAClock:
    # the class-suffix exemption applies only inside queue.py
    def now(self) -> float:
        return time.monotonic()
