"""REPRO-SESSION fixture: VolunteerSession state poked from outside its own
methods — the session desynchronizes from the server's lease table."""


def drop_ticket_behind_servers_back(sess):
    sess.task = None                         # REPRO-SESSION fires here
    sess.tag = -1                            # and here


def fake_progress(sess, version: int):
    sess.lease_latest = version              # and here
    sess._handed = False                     # and here (private state too)


def own_methods_are_fine(self):
    # a receiver literally named ``self`` is the session mutating itself
    self.task = None
