"""Seeded fixture: a lock-order inversion hidden behind a LOCK-FREE helper.

``forward`` nests a -> b only through ``middle`` — a method that takes no
lock itself, so single-level call resolution (resolving only calls made
while a lock is held inside the callee) never reaches ``inner_b`` and the
inversion against ``backward`` goes unreported. Transitive resolution must
surface the cycle: forward holds _a and (two calls deep) takes _b, while
backward holds _b and takes _a.
"""
import threading


class HiddenInversion:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    # -- the a -> b path, laundered through a lock-free intermediary --------
    def inner_b(self):
        with self._b:
            pass

    def middle(self):
        # no lock taken here: this frame is invisible to a depth-1 resolver
        self.inner_b()

    def forward(self):
        with self._a:
            self.middle()

    # -- the b -> a path, direct --------------------------------------------
    def backward(self):
        with self._b:
            with self._a:
                pass
