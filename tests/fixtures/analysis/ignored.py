"""Escape-hatch fixture: every violation here carries a rule-scoped
``# analysis: ignore[...]`` comment, so the file is clean — including under
--strict (no stale ignores)."""
import time


def profiling_probe() -> float:
    # this module measures the host, not lease time
    return time.perf_counter()               # analysis: ignore[REPRO-TIME]


def stamp() -> float:
    # a standalone ignore comment covers the following line
    # analysis: ignore[REPRO-TIME]
    return time.monotonic()
