"""Mutation fixture: the op-log fsync dropped from the gateway applier.

The historical bug shape: a gateway acknowledged forwarded ops (leases,
acks, publishes routed over from a peer via ``Forward``) after appending
them to its op log but BEFORE the append was fsynced. The reply races the
disk: kill -9 the gateway in that window and the op is acknowledged
everywhere — the origin gateway returned the reply to its volunteer — yet
absent from what the adopting peer replays from base + durable log. The
work silently vanishes at failover; nothing crashes, training just loses
committed progress, which is exactly the class of bug only an exhaustive
interleaving search catches.

``configure()`` plants the mutation via ``oplog_fsync=False`` (every
logged op is acknowledged-but-volatile); the checker must report a
``no-lost-forward`` violation whose shrunk trace is two steps — one
remotely-homed lease, then the owner's crash. The same world with the
fsync intact (``oplog_fsync=True``) must explore clean.
"""
from repro.analysis.mc import GatewayMCConfig


def configure() -> GatewayMCConfig:
    return GatewayMCConfig(
        policy="sync", n_volunteers=2, n_versions=1, n_mb=2,
        visibility_timeout=10.0,
        n_gateways=2, gw_crashable=(0,), max_gw_crashes=1,
        oplog_fsync=False,                                    # the bug
    )


#: ample budget — the violation surfaces within ~50 states: the crash
#: corner sits right under the first forwarded op
BUDGET = {"max_states": 20000, "max_depth": 12, "max_seconds": 30.0}
