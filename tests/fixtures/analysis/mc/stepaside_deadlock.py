"""Mutation fixture: the PR 5 step-aside deadlock, as a checkable world.

The historical bug: a volunteer that reached the reduce barrier parked on
the results queue while HOLDING the reduce lease, with no step-aside path.
If the only other volunteer crashed holding an unfinished map lease, expiry
requeued that map ticket — but nobody could take it: the survivor was parked
on a publish-kind wait for a barrier that could never fill, over a transport
whose wake for the requeued task it never subscribed to. The fleet wedged
with work pending: a textbook lost-progress deadlock the gateway fixed by
releasing the held ticket (``release(front=False)``) before parking.

``configure()`` rebuilds exactly that world minus the fix
(``allow_release=False``): the checker must report a ``deadlock-freedom``
violation with a shrunk, replayable trace. Flipping ``allow_release=True``
on the same world (the shipped engines' behavior) must explore clean — the
regression tests assert both directions.
"""
from repro.analysis.mc import MCConfig


def configure() -> MCConfig:
    return MCConfig(
        policy="sync", n_volunteers=2, n_versions=2, n_mb=2,
        visibility_timeout=10.0, crashable=("w0",), max_crashes=1,
        rejoin=False,               # the crashed incarnation never returns
        allow_release=False,        # the PR 5 bug: no step-aside escape
    )


#: the budget at which the deadlock is known reachable (depth ~15); tests
#: and the CLI fixture leg pass these so discovery does not depend on the
#: driver's defaults
BUDGET = {"max_states": 30000, "max_depth": 16, "max_seconds": 30.0}
