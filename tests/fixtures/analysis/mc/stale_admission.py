"""Mutation fixture: the bounded-staleness admission off-by-one.

The historical bug: the admission predicate compared ``latest - computed_at
<= staleness + 1`` (an inclusive-bound slip), so under ``staleness:1`` a
gradient computed at v0 could be applied onto v2 — staleness 2, one more
than the policy's declared SSP guarantee. Nothing crashes: training quietly
converges worse, which is why only an exhaustive interleaving search (or a
sharp-eyed reviewer) catches it.

``configure()`` plants the buggy policy via ``MCConfig.policy_object``; the
checker must report an ``admission-soundness`` violation whose shrunk trace
is pure protocol moves — three volunteers racing their commits, no fault
injection needed. The honest ``staleness:1`` policy on the same world must
explore clean.
"""
from dataclasses import dataclass

from repro.analysis.mc import MCConfig
from repro.core.aggregation import BoundedStaleness


@dataclass(frozen=True)
class OffByOneStaleness(BoundedStaleness):
    """BoundedStaleness with the seeded admission slip re-introduced."""

    def admit(self, computed_at: int, latest: int) -> bool:
        return (latest - computed_at) <= self.staleness + 1   # the bug


def configure() -> MCConfig:
    return MCConfig(
        policy="staleness:1", n_volunteers=3, n_versions=3, n_mb=2,
        visibility_timeout=10.0,
        policy_object=OffByOneStaleness(staleness=1),
    )


#: ample budget — the violation surfaces within ~25 states, fault-free
BUDGET = {"max_states": 30000, "max_depth": 24, "max_seconds": 30.0}
