"""SPMD step semantics on the host mesh (1 CPU device).

The key contract: the compiled train_step with N-way gradient accumulation
computes EXACTLY the same update as the unjitted full-batch reference —
the L2 form of the paper's Table-4 invariance (map count doesn't change
the model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import InputShape
from repro.distributed import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.optim import make as make_opt

RT = Runtime(remat=False)


def _mk_batch(spec, vocab, seed=0):
    rng = np.random.RandomState(seed)
    out = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.randint(0, vocab, size=s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.randn(*s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "falcon-mamba-7b",
                                  "whisper-base", "internvl2-1b"])
def test_grad_accumulation_invariance(arch):
    """n_micro=4 accumulated grads == n_micro=1 full-batch grads (same data)."""
    cfg = C.get_smoke(arch).replace(dtype="float32")
    mesh = make_host_mesh()
    shape = InputShape("t", 16, 8, "train")
    opt = make_opt("sgd", 0.1)

    b1 = ST.bind_train(mesh, cfg, RT, opt, shape, num_microbatches=1,
                       donate=False)
    b4 = ST.bind_train(mesh, cfg, RT, opt, shape, num_microbatches=4,
                       donate=False)
    assert b1["n_micro"] == 1 and b4["n_micro"] == 4

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = _mk_batch(b1["batch_shape"], cfg.vocab)

    p1, s1, m1 = b1["step"](params, state, batch)
    p4, s4, m4 = b4["step"](params, state, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)


def test_train_step_learns():
    cfg = C.get_smoke("minitron-4b").replace(dtype="float32")
    mesh = make_host_mesh()
    shape = InputShape("t", 16, 8, "train")
    opt = make_opt("adamw", 3e-3)
    b = ST.bind_train(mesh, cfg, RT, opt, shape, donate=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = _mk_batch(b["batch_shape"], cfg.vocab)    # fixed batch: memorize
    losses = []
    for _ in range(8):
        params, state, mets = b["step"](params, state, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0], losses


def test_decode_step_binds_and_runs():
    cfg = C.get_smoke("jamba-v0.1-52b").replace(dtype="float32")
    mesh = make_host_mesh()
    shape = InputShape("d", 32, 4, "decode")
    b = ST.bind_decode(mesh, cfg, RT, shape)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 4, 32, dtype=jnp.float32)
    tok = jnp.zeros((4,), jnp.int32)
    logits, cache2 = b["step"](params, cache, tok, jnp.int32(5))
    assert logits.shape == (4, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_step_binds_and_runs():
    cfg = C.get_smoke("deepseek-moe-16b").replace(dtype="float32")
    mesh = make_host_mesh()
    shape = InputShape("p", 16, 2, "prefill")
    b = ST.bind_prefill(mesh, cfg, RT, shape)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 2, 16, dtype=jnp.float32)
    batch = _mk_batch(b["batch_shape"], cfg.vocab)
    logits, cache2 = b["step"](params, batch, cache)
    assert logits.shape == (2, cfg.vocab)


def test_microbatch_count_respects_mesh():
    pol = ST.SH.ShardingPolicy(("data", "model"), (16, 16))
    shp = InputShape("t", 4096, 256, "train")
    # 256/16 = 16 per device -> the paper's 16 accumulation steps fit exactly
    assert ST._microbatch_count(shp, pol) == 16
    pol2 = ST.SH.ShardingPolicy(("pod", "data", "model"), (2, 16, 16))
    # 256/32 = 8 per device -> fall back to 8
    assert ST._microbatch_count(shp, pol2) == 8
