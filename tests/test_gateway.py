"""Gateway: the volunteer protocol over a real loopback socket.

The same engine-free volunteer loop (``run_volunteer`` on a
``VolunteerSession``) must complete a training run over a TCP socket exactly
as it does over direct in-process calls — the end-to-end proof that the
sans-IO protocol layer owns ALL the rules and the transport is swappable.
"""
from __future__ import annotations

import threading

import pytest

from repro.core.gateway import (GatewayServer, SocketTransport, run_volunteer)
from repro.core.simulator import SyntheticProblem
from repro.core.transport import InProcessTransport

N_VERSIONS, N_MB = 3, 4
N_TASKS = N_VERSIONS * (N_MB + 1)


def _problem():
    return SyntheticProblem(n_versions=N_VERSIONS, n_mb=N_MB)


@pytest.fixture
def server():
    s = GatewayServer(_problem(), n_versions=N_VERSIONS)
    s.start()
    yield s
    s.close()


def test_single_volunteer_over_socket(server):
    transport = SocketTransport("127.0.0.1", server.port, "sock0")
    final, tasks = run_volunteer(transport, "sock0", N_VERSIONS)
    transport.close()
    assert final == N_VERSIONS
    assert tasks == N_TASKS
    assert transport.bytes_moved > 0
    assert server.ds.latest_version == N_VERSIONS
    assert server.done.is_set()


def test_socket_run_matches_inprocess_run(server):
    ref_server = GatewayServer(_problem(), n_versions=N_VERSIONS)
    ref = run_volunteer(InProcessTransport(ref_server.endpoint), "ref",
                        N_VERSIONS)
    ref_server.close()
    transport = SocketTransport("127.0.0.1", server.port, "sock0")
    out = run_volunteer(transport, "sock0", N_VERSIONS)
    transport.close()
    assert out == ref == (N_VERSIONS, N_TASKS)


def test_barrierless_policy_over_socket_uses_server_applier():
    """Under staleness:<s> the gateway hosts a ServerApplier: the socket
    volunteer commits every update with one SubmitUpdate and never sends a
    PublishModel or an admission-time FetchModel pair."""
    s = GatewayServer(_problem(), n_versions=N_VERSIONS, policy="staleness:1")
    s.start()
    try:
        n_updates = s.n_updates
        assert n_updates == N_VERSIONS * N_MB      # one version per gradient
        transport = SocketTransport("127.0.0.1", s.port, "thin0")
        final, tasks = run_volunteer(transport, "thin0", n_updates,
                                     policy="staleness:1")
        sent = dict(transport.sent)
        transport.close()
        assert final == n_updates
        assert tasks == n_updates
        assert sent.get("SubmitUpdate") == n_updates
        assert "PublishModel" not in sent
        assert s.endpoint.applier.applied == n_updates
        assert s.done.is_set()
    finally:
        s.close()


def test_two_volunteers_share_the_run(server):
    """Cross-client coordination over the socket: pushed Wake/VersionReady
    frames must wake the volunteer blocked on the other one's progress."""
    results = {}

    def worker(vid):
        transport = SocketTransport("127.0.0.1", server.port, vid)
        results[vid] = run_volunteer(transport, vid, N_VERSIONS)
        transport.close()

    threads = [threading.Thread(target=worker, args=(f"gw{i}",), daemon=True)
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "volunteer deadlocked over the socket"
    finals = [results[v][0] for v in sorted(results)]
    tasks = [results[v][1] for v in sorted(results)]
    assert finals == [N_VERSIONS, N_VERSIONS]
    assert sum(tasks) == N_TASKS          # every task done exactly once
    assert server.ds.latest_version == N_VERSIONS
