"""Gateway: the volunteer protocol over a real loopback socket.

The same engine-free volunteer loop (``run_volunteer`` on a
``VolunteerSession``) must complete a training run over a TCP socket exactly
as it does over direct in-process calls — the end-to-end proof that the
sans-IO protocol layer owns ALL the rules and the transport is swappable.
"""
from __future__ import annotations

import socket
import struct
import threading
import time

import pytest

from repro.core import gateway
from repro.core.gateway import (GatewayServer, SocketTransport,
                                WsClientTransport, _recv_frame, _send_frame,
                                run_volunteer)
from repro.core.protocol import Hello
from repro.core.simulator import SyntheticProblem
from repro.core.transport import InProcessTransport

N_VERSIONS, N_MB = 3, 4
N_TASKS = N_VERSIONS * (N_MB + 1)


def _problem():
    return SyntheticProblem(n_versions=N_VERSIONS, n_mb=N_MB)


@pytest.fixture
def server():
    s = GatewayServer(_problem(), n_versions=N_VERSIONS)
    s.start()
    yield s
    s.close()


def test_single_volunteer_over_socket(server):
    transport = SocketTransport("127.0.0.1", server.port, "sock0")
    final, tasks = run_volunteer(transport, "sock0", N_VERSIONS)
    transport.close()
    assert final == N_VERSIONS
    assert tasks == N_TASKS
    assert transport.bytes_moved > 0
    assert server.ds.latest_version == N_VERSIONS
    assert server.done.is_set()


def test_socket_run_matches_inprocess_run(server):
    ref_server = GatewayServer(_problem(), n_versions=N_VERSIONS)
    ref = run_volunteer(InProcessTransport(ref_server.endpoint), "ref",
                        N_VERSIONS)
    ref_server.close()
    transport = SocketTransport("127.0.0.1", server.port, "sock0")
    out = run_volunteer(transport, "sock0", N_VERSIONS)
    transport.close()
    assert out == ref == (N_VERSIONS, N_TASKS)


def test_barrierless_policy_over_socket_uses_server_applier():
    """Under staleness:<s> the gateway hosts a ServerApplier: the socket
    volunteer commits every update with one SubmitUpdate and never sends a
    PublishModel or an admission-time FetchModel pair."""
    s = GatewayServer(_problem(), n_versions=N_VERSIONS, policy="staleness:1")
    s.start()
    try:
        n_updates = s.n_updates
        assert n_updates == N_VERSIONS * N_MB      # one version per gradient
        transport = SocketTransport("127.0.0.1", s.port, "thin0")
        final, tasks = run_volunteer(transport, "thin0", n_updates,
                                     policy="staleness:1")
        sent = dict(transport.sent)
        transport.close()
        assert final == n_updates
        assert tasks == n_updates
        assert sent.get("SubmitUpdate") == n_updates
        assert "PublishModel" not in sent
        assert s.endpoint.applier.applied == n_updates
        assert s.done.is_set()
    finally:
        s.close()


def test_two_volunteers_share_the_run(server):
    """Cross-client coordination over the socket: pushed Wake/VersionReady
    frames must wake the volunteer blocked on the other one's progress."""
    results = {}

    def worker(vid):
        transport = SocketTransport("127.0.0.1", server.port, vid)
        results[vid] = run_volunteer(transport, vid, N_VERSIONS)
        transport.close()

    threads = [threading.Thread(target=worker, args=(f"gw{i}",), daemon=True)
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "volunteer deadlocked over the socket"
    finals = [results[v][0] for v in sorted(results)]
    tasks = [results[v][1] for v in sorted(results)]
    assert finals == [N_VERSIONS, N_VERSIONS]
    assert sum(tasks) == N_TASKS          # every task done exactly once
    assert server.ds.latest_version == N_VERSIONS


# ---------------------------------------------------------------------------
# dual dialect: the same run over WebSocket framing
# ---------------------------------------------------------------------------

def test_ws_volunteer_matches_tcp_run(server):
    """The tentpole equivalence: a WebSocket-framed volunteer finishes the
    identical run a native-TCP volunteer does, on the same server port."""
    ref_server = GatewayServer(_problem(), n_versions=N_VERSIONS)
    ref_server.start()
    ref_tr = SocketTransport("127.0.0.1", ref_server.port, "tcp0")
    ref = run_volunteer(ref_tr, "tcp0", N_VERSIONS)
    ref_tr.close()
    ref_server.close()
    transport = WsClientTransport("127.0.0.1", server.port, "ws0")
    out = run_volunteer(transport, "ws0", N_VERSIONS)
    transport.close()
    assert out == ref == (N_VERSIONS, N_TASKS)
    assert server.ds.latest_version == N_VERSIONS


def test_ws_and_tcp_volunteers_share_one_run(server):
    """One port, both dialects, one run: cross-dialect Wake/VersionReady
    pushes must coordinate a WS volunteer with a TCP volunteer."""
    results = {}

    def worker(vid, cls):
        tr = cls("127.0.0.1", server.port, vid)
        results[vid] = run_volunteer(tr, vid, N_VERSIONS)
        tr.close()

    threads = [
        threading.Thread(target=worker, args=("ws0", WsClientTransport),
                         daemon=True),
        threading.Thread(target=worker, args=("tcp0", SocketTransport),
                         daemon=True)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "volunteer deadlocked across dialects"
    assert [results[v][0] for v in sorted(results)] == [N_VERSIONS] * 2
    assert sum(results[v][1] for v in results) == N_TASKS


def test_non_ws_http_request_is_rejected_cleanly(server):
    """A GET that is not a well-formed WS upgrade gets a 400 and a close,
    and the server stays healthy for the next volunteer."""
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    sock.sendall(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n")
    sock.settimeout(5)
    data = b""
    while b"\r\n\r\n" not in data:
        chunk = sock.recv(4096)
        if not chunk:
            break
        data += chunk
    sock.close()
    assert data.startswith(b"HTTP/1.1 400")
    tr = SocketTransport("127.0.0.1", server.port, "after400")
    assert run_volunteer(tr, "after400", N_VERSIONS) == (N_VERSIONS, N_TASKS)
    tr.close()


# ---------------------------------------------------------------------------
# satellite regressions: the socket framing bugfix pass
# ---------------------------------------------------------------------------

def test_sock_timeout_restored_when_exception_escapes():
    """Regression (timeout leak): an exception raised inside a timed
    section must not leak the scoped timeout onto the socket — the next
    frame read would get a surprise socket.timeout and desync the stream."""
    a, b = socket.socketpair()
    try:
        a.settimeout(7.5)
        with pytest.raises(RuntimeError):
            with gateway._sock_timeout(a, 0.01):
                assert a.gettimeout() == 0.01
                raise RuntimeError("injected fault mid-section")
        assert a.gettimeout() == 7.5          # restored despite the raise
        # nesting restores the OUTER scope's value, not the default
        with gateway._sock_timeout(a, 1.0):
            with gateway._sock_timeout(a, 2.0):
                assert a.gettimeout() == 2.0
            assert a.gettimeout() == 1.0
        assert a.gettimeout() == 7.5
    finally:
        a.close()
        b.close()


def test_wait_notification_fault_does_not_leak_timeout(server, monkeypatch):
    """The integration face of the same bug: a decode fault inside a timed
    wait_notification must leave the socket back at blocking (None), so the
    transport is still usable for aligned reads afterwards."""
    tr = SocketTransport("127.0.0.1", server.port, "leak0")
    assert tr.sock.gettimeout() is None
    assert tr.wait_notification(0.2) is None      # clean idle timeout
    assert tr.sock.gettimeout() is None

    def boom(sock):
        raise RuntimeError("injected decode fault")

    monkeypatch.setattr(gateway, "_recv_frame", boom)
    with pytest.raises(RuntimeError, match="injected"):
        tr.wait_notification(0.2)
    monkeypatch.undo()
    assert tr.sock.gettimeout() is None           # no stale 0.2 s timeout
    # the stream is still aligned: a real call round-trips fine
    from repro.core.protocol import LatestReq
    assert tr.call(LatestReq()).version == 0
    tr.close()


def test_oversize_length_prefix_closes_connection_server_side(server):
    """Regression (MAX_FRAME): a hostile u32 length prefix must close the
    connection with a logged protocol error — never drive an allocation —
    and the server must stay healthy for the next volunteer."""
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    _send_frame(sock, Hello("big0"))
    assert _recv_frame(sock) is not None          # bound normally first
    sock.sendall(struct.pack(">I", gateway.MAX_FRAME + 1))
    sock.settimeout(5)
    assert sock.recv(4096) == b""                 # server closed on us
    sock.close()
    tr = SocketTransport("127.0.0.1", server.port, "afterbig")
    assert run_volunteer(tr, "afterbig", N_VERSIONS) == (N_VERSIONS, N_TASKS)
    tr.close()


def test_oversize_length_prefix_closes_connection_client_side():
    """Same cap on the client: a corrupt length prefix from the server side
    surfaces as a clean ConnectionError, not a multi-GB recv loop."""
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]

    def fake_server():
        conn, _ = lsock.accept()
        conn.recv(1 << 16)                        # swallow the Hello frame
        conn.sendall(struct.pack(">I", gateway.MAX_FRAME + 1) + b"junk")
        try:
            conn.recv(1)                          # hold open until client acts
        except OSError:
            pass                                  # client reset us — expected

    t = threading.Thread(target=fake_server, daemon=True)
    t.start()
    with pytest.raises(ConnectionError):
        SocketTransport("127.0.0.1", port, "dupe0", connect_timeout=5)
    lsock.close()


def test_mid_frame_stall_tears_down_via_endpoint_disconnect(
        server, monkeypatch):
    """Regression (half-open teardown): a client that sends a length header
    and then goes silent must be torn down through endpoint.disconnect —
    not a bare close — so its waiters/subscriptions are dropped."""
    monkeypatch.setattr(gateway, "FRAME_STALL_TIMEOUT", 0.3)
    dropped = []
    orig = server.endpoint.disconnect
    monkeypatch.setattr(server.endpoint, "disconnect",
                        lambda c: (dropped.append(c), orig(c))[1])
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    _send_frame(sock, Hello("stall0"))
    assert _recv_frame(sock) is not None          # registered as a consumer
    sock.sendall(struct.pack(">I", 64))           # header, then... nothing
    deadline = time.monotonic() + 5.0
    while "stall0" not in dropped:
        assert time.monotonic() < deadline, \
            "server never disconnected the mid-frame staller"
        time.sleep(0.02)
    sock.settimeout(5)
    assert sock.recv(4096) == b""                 # connection torn down
    sock.close()


def test_volunteer_killed_between_header_and_body(server, monkeypatch):
    """The abrupt-death variant: the socket dies (not stalls) between the
    length header and the body — same teardown path, same disconnect."""
    monkeypatch.setattr(gateway, "FRAME_STALL_TIMEOUT", 0.3)
    dropped = []
    orig = server.endpoint.disconnect
    monkeypatch.setattr(server.endpoint, "disconnect",
                        lambda c: (dropped.append(c), orig(c))[1])
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    _send_frame(sock, Hello("corpse0"))
    assert _recv_frame(sock) is not None
    sock.sendall(struct.pack(">I", 64))           # header only...
    sock.close()                                  # ...then the tab closes
    deadline = time.monotonic() + 5.0
    while "corpse0" not in dropped:
        assert time.monotonic() < deadline, \
            "server never disconnected the dead half-frame client"
        time.sleep(0.02)


# ---------------------------------------------------------------------------
# torn writes: byte-level delivery, both dialects
# ---------------------------------------------------------------------------

def _dribble(sock, data: bytes, chunk: int = 1) -> None:
    for i in range(0, len(data), chunk):
        sock.sendall(data[i:i + chunk])
        time.sleep(0.001)


def test_torn_tcp_writes_reassemble_cleanly(server):
    """A native frame arriving one byte at a time must dispatch exactly
    once, intact; a partial frame must get NO reply until completed."""
    from repro.core.protocol import LatestReq, encode_message
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    body = encode_message(Hello("torn0"))
    _dribble(sock, struct.pack(">I", len(body)) + body)
    assert _recv_frame(sock) is not None          # one intact dispatch
    # now leave a frame half-written: no reply may arrive for it
    body2 = encode_message(LatestReq())
    frame2 = struct.pack(">I", len(body2)) + body2
    sock.sendall(frame2[:len(frame2) // 2])
    sock.settimeout(0.5)
    with pytest.raises(socket.timeout):
        sock.recv(4096)                           # half a frame, no dispatch
    sock.settimeout(5)
    sock.sendall(frame2[len(frame2) // 2:])       # complete it
    reply = _recv_frame(sock)
    assert reply is not None and reply.version == 0
    sock.close()


def test_torn_ws_writes_reassemble_cleanly(server):
    """The WS equivalent, harder: the upgrade, then a Hello fragmented into
    WS continuation frames AND dribbled byte-by-byte. The server must
    dispatch the one reassembled message and reply with one WS message."""
    from repro.core import wsframing as wf
    from repro.core.protocol import decode_message, encode_message
    sock = socket.create_connection(("127.0.0.1", server.port), timeout=5)
    request, key = wf.client_handshake_request(f"127.0.0.1:{server.port}")
    _dribble(sock, request, chunk=3)
    handshake = wf.ClientHandshake(key)
    sock.settimeout(5)
    while not handshake.done:
        handshake.feed(sock.recv(4096))
    framer = wf.client_framer()
    if handshake.leftover:
        framer.feed(handshake.leftover)
    wire = framer.send_message(encode_message(Hello("wstorn0")),
                               fragment_size=5)
    _dribble(sock, wire)                          # fragments, byte by byte
    events = []
    while not events:
        events = framer.feed(sock.recv(4096))
    assert len(events) == 1 and isinstance(events[0], wf.Message)
    assert decode_message(events[0].data) is not None
    sock.close()
