"""repro.analysis — every rule proven to fire on its seeded fixture and to
stay silent on the shipped tree, the lock-inversion fixture caught both
statically and under runtime instrumentation, and the CLI contract
(--strict exits 0 on src/, non-zero on each fixture)."""
import importlib.util
import os
import pathlib
import subprocess
import sys
import threading
from collections import deque
from dataclasses import dataclass
from typing import Any

import pytest

from repro.analysis import base, locks, rules, runtime, schema
from repro.analysis.runtime import Analysis
from repro.core import gateway

ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"
CORE = ROOT / "src" / "repro" / "core"


def _load_fixture(name: str):
    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# rules: each fixture fires its rule; the shipped tree is clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,rule,n_min", [
    ("wallclock.py", "REPRO-TIME", 4),
    ("simulator.py", "REPRO-LAYER", 3),
    ("session_mutation.py", "REPRO-SESSION", 4),
    ("swallow.py", "REPRO-EXCEPT", 2),
])
def test_rule_fires_on_fixture(fixture, rule, n_min):
    violations, stale = rules.check_file(FIXTURES / fixture)
    fired = [v for v in violations if v.rule == rule]
    assert len(fired) >= n_min, violations
    # and ONLY that rule: fixtures are single-rule by construction
    assert {v.rule for v in violations} == {rule}
    assert stale == []


def test_rules_clean_on_core_tree():
    violations, stale = rules.check_paths(sorted(CORE.glob("*.py")))
    assert violations == [], "\n".join(map(str, violations))
    assert stale == [], "\n".join(map(str, stale))


def test_fixture_negative_space_not_flagged():
    # each fixture also contains a deliberately-legal variant; the counts
    # above being exact minimums, make the negatives explicit on one file
    violations, _ = rules.check_file(FIXTURES / "simulator.py")
    assert not any(v.line >= 20 for v in violations), violations


def test_ignore_escape_hatch_and_strict_staleness(tmp_path):
    clean, stale = rules.check_file(FIXTURES / "ignored.py")
    assert clean == [] and stale == []
    # an ignore that suppresses nothing is itself a strict-mode violation
    p = tmp_path / "stale.py"
    p.write_text("x = 1  # analysis: ignore[REPRO-TIME]\n")
    clean, stale = rules.check_file(p)
    assert clean == []
    assert [v.rule for v in stale] == ["ANALYSIS-IGNORE"]


def test_ignore_is_rule_scoped(tmp_path):
    # naming the WRONG rule does not excuse the finding
    p = tmp_path / "wrong.py"
    p.write_text("import time\n"
                 "t = time.monotonic()  # analysis: ignore[REPRO-LAYER]\n")
    clean, stale = rules.check_file(p)
    assert [v.rule for v in clean] == ["REPRO-TIME"]
    assert [v.rule for v in stale] == ["ANALYSIS-IGNORE"]


# ---------------------------------------------------------------------------
# locks: static half
# ---------------------------------------------------------------------------

def test_static_cycle_found_in_inversion_fixture():
    vs = locks.check([FIXTURES / "lock_inversion.py"])
    assert len(vs) == 1 and vs[0].rule == "LOCK-ORDER"
    assert "lock_inversion._a" in vs[0].message
    assert "lock_inversion._b" in vs[0].message


def test_static_graph_clean_on_core():
    assert locks.check(locks.default_paths()) == []


def test_static_graph_sees_gateway_locks():
    lks, edges = locks.lock_graph(locks.default_paths())
    # the seam (_make_lock) must still register as a lock factory
    assert {"gateway._lock", "gateway._snap_lock"} <= lks
    # dispatch must never wait on the fsync writer: the fsync split forbids
    # the _lock -> _snap_lock direction. The op-log flusher holds _snap_lock
    # and retakes _lock ONLY for the bounded buffer swap (fsyncs run after
    # _lock is released), so the reverse edge is the one legal nesting.
    assert ("gateway._lock", "gateway._snap_lock") not in edges, edges
    assert ("gateway._snap_lock", "gateway._lock") in edges, edges


def test_static_cycle_found_through_lock_free_intermediate():
    # depth-2 chain: forward holds _a -> calls a LOCK-FREE helper -> helper
    # takes _b; only transitive call resolution sees the inversion
    vs = locks.check([FIXTURES / "lock_depth2.py"])
    assert [v.rule for v in vs] == ["LOCK-ORDER"]
    assert "lock_depth2._a" in vs[0].message
    assert "lock_depth2._b" in vs[0].message


def test_foreign_receiver_calls_do_not_resolve(tmp_path):
    # self.other.snapshot() must NOT be conflated with this module's own
    # snapshot() — the gateway/_encode_snapshot false positive
    p = tmp_path / "foreign.py"
    p.write_text(
        "import threading\n"
        "class S:\n"
        "    def __init__(self, other):\n"
        "        self._a = threading.Lock()\n"
        "        self._b = threading.Lock()\n"
        "        self.other = other\n"
        "    def snapshot(self):\n"
        "        with self._b:\n"
        "            pass\n"
        "    def outer(self):\n"
        "        with self._a:\n"
        "            self.other.snapshot()\n")
    _, edges = locks.lock_graph([p])
    assert ("foreign._a", "foreign._b") not in edges


def test_transitive_edges_via_same_module_calls(tmp_path):
    p = tmp_path / "nested.py"
    p.write_text(
        "import threading\n"
        "class S:\n"
        "    def __init__(self):\n"
        "        self._a = threading.Lock()\n"
        "        self._b = threading.Lock()\n"
        "    def inner(self):\n"
        "        with self._b:\n"
        "            pass\n"
        "    def outer(self):\n"
        "        with self._a:\n"
        "            self.inner()\n")
    _, edges = locks.lock_graph([p])
    assert ("nested._a", "nested._b") in edges


# ---------------------------------------------------------------------------
# locks: runtime half
# ---------------------------------------------------------------------------

def test_runtime_catches_inversion_in_fixture_class():
    fx = _load_fixture("lock_inversion")
    mon = Analysis()
    inv = fx.Inverted()
    inv._a = mon.make_lock("fx._a")
    inv._b = mon.make_lock("fx._b")
    inv.forward()
    assert mon.violations == []              # one order alone is legal
    inv.backward()
    assert [v.rule for v in mon.violations] == ["LOCK-ORDER"]
    assert mon.report(stream=open(os.devnull, "w")) == 1


def test_runtime_catches_inversion_against_static_graph():
    # the opposing path never RUNS — only the static graph knows it exists
    static = locks.static_edges([FIXTURES / "lock_inversion.py"])
    assert ("lock_inversion._a", "lock_inversion._b") in static
    mon = Analysis(static_edges=static)
    a = mon.make_lock("lock_inversion._a")
    b = mon.make_lock("lock_inversion._b")
    with b:
        with a:                              # inverts the static a -> b
            pass
    assert [v.rule for v in mon.violations] == ["LOCK-ORDER"]
    assert "static graph" in mon.violations[0].message


def test_runtime_self_deadlock_fails_fast():
    mon = Analysis()
    lk = mon.make_lock("l")
    lk.acquire()
    with pytest.raises(RuntimeError):
        lk.acquire()
    assert [v.rule for v in mon.violations] == ["LOCK-SELF"]


def test_blocking_under_guard_lock_flagged():
    mon = Analysis()
    guard = mon.make_lock("gateway._lock", guard=True)
    plain = mon.make_lock("gateway._snap_lock")
    with plain:
        mon.note_blocking("snapshot-fsync")  # non-guard lock: fine
    assert mon.violations == []
    with guard:
        mon.note_blocking("socket-recv")
    assert [v.rule for v in mon.violations] == ["LOCK-BLOCK"]


def test_parked_holder_invariant():
    mon = Analysis()
    mon.note_park("v", holding=False, timed=False)   # idle park: fine
    mon.note_park("v", holding=True, timed=True)     # heartbeat wakes it: fine
    assert mon.violations == []
    mon.note_park("v", holding=True, timed=False)    # PR 5's deadlock shape
    assert [v.rule for v in mon.violations] == ["PARKED-HOLDER"]


def test_gateway_wait_reports_parked_holder(monkeypatch):
    """An untimed-wait transport + a held ticket through the REAL _wait
    path must trip the regression guard."""
    mon = Analysis()
    monkeypatch.setattr(gateway, "_monitor", lambda: mon)

    class UntimedTransport:
        timed_waits = False

        def wait_notification(self, timeout=None):
            return object()

    assert gateway._wait(UntimedTransport(), deque(), 0.5, holding=True)
    assert [v.rule for v in mon.violations] == ["PARKED-HOLDER"]
    # the shipped volunteer always passes a timeout over timed transports
    mon2 = Analysis()
    monkeypatch.setattr(gateway, "_monitor", lambda: mon2)

    class TimedTransport(UntimedTransport):
        timed_waits = True

    assert gateway._wait(TimedTransport(), deque(), 0.5, holding=True)
    assert mon2.violations == []


def test_monitored_locks_work_across_threads():
    mon = Analysis()
    lk = mon.make_lock("shared")
    hits = []

    def worker():
        for _ in range(200):
            with lk:
                hits.append(1)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(hits) == 800 and mon.violations == []


def test_instrument_singleton_loads_static_graph():
    Analysis.reset()
    try:
        mon = Analysis.instrument()
        assert mon is Analysis.instrument()
        assert isinstance(mon._static, set)
    finally:
        Analysis.reset()


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

def test_schema_clean_on_tree():
    vs = schema.run()
    assert vs == [], "\n".join(map(str, vs))


def test_schema_doc_check_fires_on_incomplete_doc():
    vs = schema.check_doc(FIXTURES / "protocol_missing.md")
    assert vs and all(v.rule == "SCHEMA-DOC" for v in vs)
    named = " ".join(v.message for v in vs)
    assert "LeaseReq" in named and "MapTask" in named
    assert "Hello " not in named             # the two documented ones pass


def test_rogue_type_fails_roundtrip_and_partition():
    @dataclass(frozen=True)
    class Rogue:
        payload: Any

    vs = schema.run(extra_types=(Rogue,))
    fired = {v.rule for v in vs if "Rogue" in v.message}
    # unregistered -> can't cross the wire, fits no role, undocumented
    assert fired == {"SCHEMA-ROUNDTRIP", "SCHEMA-PARTITION", "SCHEMA-DOC"}


def test_schema_samples_construct_every_registered_type():
    for name, cls in schema.registered_types().items():
        inst = schema.sample(cls)
        assert type(inst).__name__ == name


def test_schema_mc_coverage_fires_on_partial_ledger():
    fx = _load_fixture("mc_partial_coverage")
    vs = schema.check_mc_coverage(fx.COVERED)
    assert all(v.rule == "SCHEMA-MC" for v in vs)
    assert sorted(m for v in vs for m in fx.MISSING if m in v.message) == \
        sorted(fx.MISSING)
    assert len(vs) == len(fx.MISSING)


# ---------------------------------------------------------------------------
# the mc pass: seeded historical bugs rediscovered with replayable repros
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,rule,invariant", [
    ("mc/stepaside_deadlock.py", "MC-DEADLOCK", "deadlock-freedom"),
    ("mc/stale_admission.py", "MC-ADMIT", "admission-soundness"),
])
def test_mc_rediscovers_seeded_bug_with_replayable_repro(fixture, rule,
                                                         invariant):
    from repro.analysis.mc import replay_payload, run_mc
    path = str(FIXTURES / fixture)
    vs = run_mc(fixture=path, max_states=30000, max_depth=24,
                max_seconds=30.0)
    assert vs, "seeded bug not rediscovered"
    assert vs[0].rule == rule
    assert "minimized" in vs[0].message
    # the inline payload is a complete runnable repro: parse it back out and
    # replay it through the chaos harness entry point
    import json as _json
    payload = _json.loads(vs[0].message[vs[0].message.index('{"'):])
    outcome = replay_payload(payload)
    assert outcome.invariant == invariant


# ---------------------------------------------------------------------------
# the CLI contract
# ---------------------------------------------------------------------------

def _cli(*argv):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=ROOT, env=env, capture_output=True, text=True)


def test_cli_strict_clean_on_shipped_tree():
    res = _cli("--strict")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "clean" in res.stdout


@pytest.mark.parametrize("argv", [
    ("--only", "rules", "--paths", "tests/fixtures/analysis/wallclock.py"),
    ("--only", "rules", "--paths", "tests/fixtures/analysis/simulator.py"),
    ("--only", "rules", "--paths",
     "tests/fixtures/analysis/session_mutation.py"),
    ("--only", "rules", "--paths", "tests/fixtures/analysis/swallow.py"),
    ("--only", "locks", "--paths",
     "tests/fixtures/analysis/lock_inversion.py"),
    ("--only", "schema", "--doc",
     "tests/fixtures/analysis/protocol_missing.md"),
    ("--mc", "--mc-fixture", "tests/fixtures/analysis/mc/stale_admission.py",
     "--mc-depth", "24"),
])
def test_cli_nonzero_on_each_violation_fixture(argv):
    res = _cli(*argv)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "violation" in res.stdout


def test_cli_ignored_fixture_clean_even_strict():
    res = _cli("--strict", "--only", "rules", "--paths",
               "tests/fixtures/analysis/ignored.py")
    assert res.returncode == 0, res.stdout + res.stderr
