"""DataServer version semantics: monotone, exactly-once publication."""
import pytest

from repro.core.dataserver import DataServer


def test_versions_monotone_and_idempotent():
    ds = DataServer()
    assert ds.latest_version == -1
    assert ds.publish_model(0, "m0")
    assert not ds.publish_model(0, "m0-dup")    # duplicate discarded
    assert ds.get_model(0) == "m0"
    assert ds.get_model(1) is None              # "task waits" signal
    assert ds.publish_model(1, "m1")
    assert ds.latest_version == 1


def test_version_gap_rejected():
    ds = DataServer()
    ds.publish_model(0, "m0")
    with pytest.raises(AssertionError):
        ds.publish_model(2, "m2")


def test_gc_keeps_recent():
    ds = DataServer()
    for v in range(5):
        ds.publish_model(v, f"m{v}")
    ds.gc_models(keep_last=2)
    assert ds.get_model(2) is None
    assert ds.get_model(4) == "m4"
    assert ds.latest_version == 4


def test_watch_version_fires_immediately_for_published_version():
    """Watching an ALREADY-committed version must fire synchronously (the
    check-then-watch pattern would otherwise lose the wake forever)."""
    ds = DataServer()
    ds.publish_model(0, "m0")
    ds.publish_model(1, "m1")
    fired = []
    ds.watch_version(0, lambda: fired.append(0))    # older than latest
    ds.watch_version(1, lambda: fired.append(1))    # exactly latest
    assert fired == [0, 1]
    ds.watch_version(2, lambda: fired.append(2))    # future: deferred
    assert fired == [0, 1]
    ds.publish_model(2, "m2")
    assert fired == [0, 1, 2]
    assert ds.watch_fires == 3


def test_kv_crud():
    ds = DataServer()
    ds.put("k", 123, nbytes=8)
    assert ds.get("k", nbytes=8) == 123
    assert ds.delete("k")
    assert not ds.delete("k")
    assert ds.bytes_written == 8 and ds.bytes_read == 8
