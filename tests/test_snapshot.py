"""Durability: snapshot/restore of queue + data servers, and the gateway's
crash-recovery pieces (wall-clock lease sweeper, server-side applier).

The contract under test is *transparency*: serializing the full live state
through real bytes and restoring it — mid-run, same process or fresh one —
must be invisible to every observer the protocol has (pending FIFO order,
in-flight deadlines, banked signals, counters, model versions, subscribers).
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.core.chaos import (ChaosEvent, ChaosSchedule, federation_census,
                              metamorphic_check, snapshot_schedule)
from repro.core.dataserver import DataServer
from repro.core.gateway import GatewayServer, SocketTransport, run_volunteer
from repro.core.protocol import decode_message, encode_message
from repro.core.queue import (Queue, QueueServer, ShardedQueueServer,
                              VirtualClock, WallClock)
from repro.core.simulator import Simulator, SyntheticProblem, VolunteerSpec
from repro.core.tasks import GradResult, MapTask


def roundtrip(state):
    """Snapshot dicts must survive the real wire codec, not just Python."""
    return decode_message(encode_message(state))


# ---------------------------------------------------------------------------
# Queue / QueueServer
# ---------------------------------------------------------------------------

def _loaded_server(vt: float = 5.0) -> QueueServer:
    qs = QueueServer(default_timeout=vt)
    for i in range(3):
        qs.publish("tasks", MapTask(0, 0, 0, i, 8))
    qs.publish("results", GradResult(0, 0, None, 16, 0.5, "w0"))
    qs.lease("tasks", "w0", now=1.0)              # in-flight, deadline 6.0
    qs.lease("tasks", "w1", now=2.0, timeout=1.0)  # deadline 3.0
    qs.nack("tasks", 1)                            # requeued to the front
    qs.publish("empty-signal", "x")
    got = qs.lease("empty-signal", "w2", now=0.0)
    qs.ack("empty-signal", got[0])
    qs.kick("empty-signal")                        # banks a signal, no waiter
    return qs


def test_queueserver_snapshot_roundtrips_full_state():
    qs = _loaded_server()
    before = federation_census(qs)
    tag_counters = {n: q._next_tag for n, q in qs.queues.items()}
    fresh = QueueServer()
    fresh.restore(roundtrip(qs.snapshot()))
    assert federation_census(fresh) == before
    for name, q in fresh.queues.items():
        q.check_invariants()
        assert q._next_tag == tag_counters[name]   # tags never collide
    # banked signal survived: the next subscribe fires immediately
    fired = []
    fresh.subscribe("empty-signal", "w9", lambda: fired.append(1))
    assert fired == [1]
    # in-flight deadlines survived into the restored server's sweep index
    # (w1's lease was nacked back, so only w0's deadline-6.0 lease remains)
    assert fresh.next_deadline() == 6.0
    assert fresh.expire_all(3.5) == 0
    assert fresh.expire_all(6.5) == 1              # w0's lease expires


def test_restore_is_transparent_to_an_interrupted_script():
    """Running a script straight vs. snapshot+restore at every step must end
    in identical state — durability cannot perturb semantics."""
    def script(qs, checkpoint):
        qs.publish("q", "a")
        checkpoint(qs)
        qs.publish("q", "b")
        tag, _ = qs.lease("q", "w0", now=0.0, timeout=2.0)
        checkpoint(qs)
        qs.ack("q", tag)
        tag2, _ = qs.lease("q", "w0", now=1.0, timeout=2.0)
        checkpoint(qs)
        qs.nack("q", tag2)
        qs.expire_all(10.0)
        checkpoint(qs)
        return federation_census(qs)

    plain = script(QueueServer(), lambda qs: None)
    durable = script(QueueServer(),
                     lambda qs: qs.restore(roundtrip(qs.snapshot())))
    assert plain == durable


def test_restore_keeps_live_waiters_in_process():
    qs = QueueServer()
    qs.declare("q")
    fired = []
    qs.subscribe("q", "w0", lambda: fired.append("w0"))
    qs.restore(roundtrip(qs.snapshot()))
    assert fired == []                             # not spuriously woken
    qs.publish("q", "task")
    assert fired == ["w0"]                         # subscription survived


def test_restore_after_crash_drops_waiters_but_keeps_leases():
    """Fresh-process restore: no live callbacks to adopt; the dead client's
    lease is still in flight and recoverable by expiry."""
    qs = _loaded_server()
    fresh = QueueServer()
    fresh.restore(roundtrip(qs.snapshot()), waiters_from={})
    assert all(q.waiters == 0 for q in fresh.queues.values())
    assert fresh.queues["tasks"].in_flight == 1    # w0 still holds tag 0
    assert fresh.expire_all(100.0) == 1


def test_sharded_snapshot_restores_ring_and_state():
    fed = ShardedQueueServer(3, default_timeout=7.0)
    for i in range(40):
        fed.publish(f"q{i:03d}", i)
    fed.add_shard()
    fed.remove_shard(1)                            # burn a shard id
    fed.lease("q001", "w0", now=0.0)
    before = federation_census(fed)
    loads_before = fed.shard_loads()
    fresh = ShardedQueueServer(1)                  # shard count comes from state
    fresh.restore(roundtrip(fed.snapshot()))
    assert federation_census(fresh) == before
    assert fresh.shard_loads() == loads_before     # identical placement
    assert fresh._sids == fed._sids                # ids (incl. burned) survive
    for q in fresh.queues.values():
        q.check_invariants()
    # routing agrees after restore: new publishes land on the same shard
    name = "q-new"
    assert fresh.shard_of(name) == fed.shard_of(name)


def test_snapshot_kind_mismatch_rejected():
    qs = QueueServer()
    fed = ShardedQueueServer(2)
    with pytest.raises(ValueError, match="not a QueueServer"):
        qs.restore(fed.snapshot())
    with pytest.raises(ValueError, match="not a ShardedQueueServer"):
        fed.restore(qs.snapshot())
    with pytest.raises(ValueError, match="not a DataServer"):
        DataServer().restore(qs.snapshot())


# ---------------------------------------------------------------------------
# DataServer: snapshot x gc_models x watch_version
# ---------------------------------------------------------------------------

def test_dataserver_snapshot_roundtrip():
    ds = DataServer()
    ds.put("corpus", "abc", nbytes=3)
    for v in range(4):
        ds.publish_model(v, f"m{v}", nbytes=10)
    fresh = DataServer()
    fresh.restore(roundtrip(ds.snapshot()))
    assert fresh.latest_version == 3
    assert fresh.get("corpus") == "abc"
    assert fresh.get_model(3) == "m3"
    assert fresh.bytes_written == ds.bytes_written
    # publication continues monotonically from the restored cursor
    assert fresh.publish_model(4, "m4")
    assert not fresh.publish_model(4, "dup")


def test_gcd_version_does_not_resurrect_on_restore():
    ds = DataServer()
    for v in range(5):
        ds.publish_model(v, f"m{v}")
    ds.gc_models(keep_last=2)
    assert ds.get_model(1) is None
    fresh = DataServer()
    fresh.restore(roundtrip(ds.snapshot()))
    assert fresh.get_model(1) is None              # stays collected
    assert fresh.get_model(2) is None
    assert fresh.get_model(4) == "m4"
    assert fresh.latest_version == 4


def test_pending_watch_survives_gc():
    ds = DataServer()
    ds.publish_model(0, "m0")
    fired = []
    ds.watch_version(3, lambda: fired.append(3))
    for v in (1, 2):
        ds.publish_model(v, f"m{v}")
        ds.gc_models(keep_last=1)                  # GC between commits
    assert fired == []
    ds.publish_model(3, "m3")
    assert fired == [3]                            # GC never ate the watch


def test_pending_watch_survives_inprocess_restore():
    ds = DataServer()
    ds.publish_model(0, "m0")
    fired = []
    ds.watch_version(2, lambda: fired.append("future"))
    ds.restore(roundtrip(ds.snapshot()))
    assert fired == []                             # still pending
    ds.publish_model(1, "m1")
    ds.publish_model(2, "m2")
    assert fired == ["future"]


def test_watch_satisfied_by_restore_fires_immediately():
    """Restoring a FURTHER-ahead snapshot commits versions the watcher was
    waiting for — the watch must fire at restore, like watch-after-publish."""
    ahead = DataServer()
    for v in range(4):
        ahead.publish_model(v, f"m{v}")
    snap = roundtrip(ahead.snapshot())
    ds = DataServer()
    ds.publish_model(0, "m0")
    fired = []
    ds.watch_version(2, lambda: fired.append(2))
    ds.watch_version(9, lambda: fired.append(9))
    ds.restore(snap)
    assert fired == [2]                            # satisfied by restore
    assert 9 in ds._watchers                       # future watch still pending


def test_gc_watch_snapshot_combined():
    """The satellite scenario end to end: gc, snapshot, restore, pending
    watch — a GC'd version stays dead, the watch stays live."""
    ds = DataServer()
    for v in range(6):
        ds.publish_model(v, f"m{v}")
    ds.gc_models(keep_last=2)
    fired = []
    ds.watch_version(7, lambda: fired.append(7))
    ds.restore(roundtrip(ds.snapshot()))
    assert ds.get_model(3) is None                 # no resurrection
    assert fired == []
    ds.publish_model(6, "m6")
    ds.publish_model(7, "m7")
    assert fired == [7]                            # watch survived both


# ---------------------------------------------------------------------------
# chaos: snapshot/restore mid-run is semantics-invisible
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["event", "poll"])
def test_metamorphic_with_snapshot_roundtrips(mode):
    schedule = snapshot_schedule(1, leavable=["x00", "x01"])
    single, sharded = metamorphic_check(schedule, mode=mode, n_shards=3)
    assert single == sharded
    assert single.final_version == 5


def test_scripted_snapshot_between_every_fault():
    """Interleave a snapshot round-trip with every other fault kind."""
    events = [ChaosEvent(1.0, "snapshot_restore"),
              ChaosEvent(2.0, "add_shard"),
              ChaosEvent(2.5, "snapshot_restore"),
              ChaosEvent(3.0, "leave", vid="x00"),
              ChaosEvent(3.5, "snapshot_restore"),
              ChaosEvent(4.0, "remove_shard", shard=0),
              ChaosEvent(4.5, "snapshot_restore")]
    schedule = ChaosSchedule(events)
    single, sharded = metamorphic_check(schedule, mode="event", n_shards=2)
    assert single == sharded


# ---------------------------------------------------------------------------
# server-side applier (Simulator): same run, fewer wire bytes
# ---------------------------------------------------------------------------

def _sim(policy: str, server_apply: bool) -> Simulator:
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=1.0e5)
    specs = [VolunteerSpec(f"v{i}", speed=1.0 + 0.1 * i) for i in range(3)]
    return Simulator(problem, specs, transport="wire", policy=policy,
                     server_apply=server_apply)


@pytest.mark.parametrize("policy", ["staleness:2", "local:4"])
def test_server_apply_is_semantics_invisible(policy):
    """Server-applied commits must produce the IDENTICAL SimResult — same
    timeline, same makespan, same task counts — except measured wire bytes,
    which must DROP (no admission fetch, no model push)."""
    client = _sim(policy, server_apply=False).run()
    server = _sim(policy, server_apply=True).run()
    assert server.wire_bytes < client.wire_bytes
    import dataclasses
    a = dataclasses.asdict(client)
    b = dataclasses.asdict(server)
    a.pop("wire_bytes"), b.pop("wire_bytes")
    assert a == b


def test_server_apply_rejects_barrier_policy():
    with pytest.raises(ValueError, match="barrierless"):
        _sim("sync", server_apply=True)


def test_server_applier_counts():
    sim = _sim("staleness:2", server_apply=True)
    res = sim.run()
    applier = sim.endpoint.applier
    assert applier.applied == res.final_version == 24
    assert applier.rejected == res.stale_discards


# ---------------------------------------------------------------------------
# gateway: wall-clock sweeper + snapshot file round-trip (in process)
# ---------------------------------------------------------------------------

def test_wall_clock_sweeper_requeues_dead_volunteers_lease():
    """A socket client that leases and then vanishes (no Bye, no ack) must
    have its ticket requeued by the sweeper on REAL time, and a survivor
    finishes the run."""
    problem = SyntheticProblem(n_versions=2, n_mb=2)
    server = GatewayServer(problem, n_versions=2, visibility_timeout=0.4,
                           sweep_interval=0.02)
    server.start()
    try:
        dead = SocketTransport("127.0.0.1", server.port, "dead")
        from repro.core.protocol import LeaseReq
        grant = dead.call(LeaseReq("initial", "dead", 0.0))
        assert hasattr(grant, "tag")
        dead.sock.close()                          # kill -9 stand-in
        # the sweeper — REAL time, no engine driving it — must requeue
        deadline = time.monotonic() + 5.0
        while server.qs.total_requeued < 1:
            assert time.monotonic() < deadline, "sweeper never expired lease"
            time.sleep(0.02)
        survivor = SocketTransport("127.0.0.1", server.port, "live")
        final, tasks = run_volunteer(survivor, "live", 2)
        survivor.close()
        assert final == 2
        assert tasks == 2 * (2 + 1)                # incl. the recovered task
    finally:
        server.close()


def test_small_fleet_survives_dead_lease_without_deadlock():
    """Regression: 2 live volunteers + 1 dead lease used to deadlock — one
    survivor parked on the reduce barrier, the other version-blocked on a
    next-round map, and the expiry-recovered map with no idle taker. The
    heartbeat (ExtendLease) + step-aside (Nack to back, take the front task)
    client rules must keep the run live."""
    from repro.core.protocol import LeaseReq
    problem = SyntheticProblem(n_versions=3, n_mb=4)
    server = GatewayServer(problem, n_versions=3, visibility_timeout=0.6,
                           sweep_interval=0.02)
    server.start()
    try:
        dead = SocketTransport("127.0.0.1", server.port, "dead")
        dead.call(LeaseReq("initial", "dead", 0.0))    # lease, then vanish
        dead.sock.close()
        results = {}

        def survive(vid):
            tr = SocketTransport("127.0.0.1", server.port, vid)
            results[vid] = run_volunteer(tr, vid, 3, heartbeat_every=0.2)
            tr.close()

        threads = [threading.Thread(target=survive, args=(f"s{i}",),
                                    daemon=True) for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive(), "survivor deadlocked"
        assert [results[v][0] for v in sorted(results)] == [3, 3]
        assert sum(r[1] for r in results.values()) >= 3 * (4 + 1)
    finally:
        server.close()


def test_gateway_snapshot_file_restore(tmp_path):
    """Mid-run file snapshot -> fresh GatewayServer boots from it and a
    volunteer completes the remaining work."""
    snap = str(tmp_path / "gw.snap")
    problem = SyntheticProblem(n_versions=3, n_mb=3)
    server = GatewayServer(problem, n_versions=3, snapshot_path=snap,
                           snapshot_every=1)
    server.start()
    # drive PART of the run, then stop mid-flight (results published, more
    # of version 0 still pending — 2 of the 3 maps, no reduce yet)
    t = SocketTransport("127.0.0.1", server.port, "gw0")
    from repro.core.protocol import MapWork, VolunteerSession
    sess = VolunteerSession("gw0", t)
    for _ in range(2):
        sess.lease(0.0)
        out = sess.advance(0.0)
        assert isinstance(out, MapWork)
        sess.finish_map(None, 0, 0.0)
    t.close()
    assert server.snapshots_written > 0
    server.close()
    # boot a FRESH server from the snapshot; a volunteer finishes the run
    revived = GatewayServer(problem, n_versions=3, restore_from=snap,
                            visibility_timeout=0.4, sweep_interval=0.02)
    revived.start()
    try:
        assert revived.ds.latest_version < 3       # genuinely mid-run
        t2 = SocketTransport("127.0.0.1", revived.port, "gw1")
        final, _ = run_volunteer(t2, "gw1", 3)
        t2.close()
        assert final == 3
        assert revived.done.is_set()
    finally:
        revived.close()


def test_gateway_snapshot_skips_readonly_requests(tmp_path):
    snap = str(tmp_path / "gw.snap")
    problem = SyntheticProblem(n_versions=2, n_mb=2)
    server = GatewayServer(problem, n_versions=2, snapshot_path=snap,
                           snapshot_every=1)
    from repro.core.protocol import DepthReq, LatestReq
    with server._lock:
        server.endpoint.handle(LatestReq())
        server.endpoint.handle(DepthReq("initial"))
        server._maybe_snapshot(LatestReq())
        server._maybe_snapshot(DepthReq("initial"))
    assert server.snapshots_written == 0           # reads are not durable ops
    server.close()


def test_watch_version_dedup_per_consumer():
    """A timed-wait client re-subscribes its version watch every wakeup; the
    endpoint must dedupe per (consumer, version) so the watcher list — and
    the VersionReady frames — do not grow with wait duration."""
    from repro.core.protocol import (Ok, ServerEndpoint, VersionReady,
                                     WatchVersion)
    qs, ds = QueueServer(), DataServer()
    ds.publish_model(0, "m0")
    delivered = []
    ep = ServerEndpoint(qs, ds, lambda c, m: delivered.append((c, m)))
    assert ep.handle(WatchVersion(1, "w0")) == Ok(True)
    for _ in range(5):                             # defensive re-subscribes
        assert ep.handle(WatchVersion(1, "w0")) == Ok(False)
    ep.handle(WatchVersion(1, "w1"))               # another consumer is fine
    ds.publish_model(1, "m1")
    assert delivered == [("w0", VersionReady(1)), ("w1", VersionReady(1))]
    # the registration is one-shot: after firing, a re-watch works again
    assert ep.handle(WatchVersion(2, "w0")) == Ok(True)


def test_lease_clock_abstraction():
    wall = WallClock()
    a = wall.now()
    assert wall.now() >= a
    ticks = [5.0]
    virt = VirtualClock(lambda: ticks[0])
    assert virt.now() == 5.0
    ticks[0] = 9.0
    assert virt.now() == 9.0                       # reads live, never stale
