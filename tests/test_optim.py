"""Optimizer unit tests: RMSprop must match the TF/Keras update rule the
paper's tfjs training used (eps OUTSIDE the sqrt), SGD/AdamW sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, rmsprop, sgd


def test_rmsprop_matches_keras_formula():
    lr, rho, eps = 0.1, 0.9, 1e-7
    opt = rmsprop(lr, rho, eps)
    p = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    g = {"w": jnp.asarray([0.3, -0.1, 0.0])}
    state = opt.init(p)
    p1, s1 = opt.update(p, state, g)
    ms = (1 - rho) * np.asarray(g["w"]) ** 2
    expect = np.asarray(p["w"]) - lr * np.asarray(g["w"]) / (np.sqrt(ms) + eps)
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-6)
    # second step accumulates ms
    p2, s2 = opt.update(p1, s1, g)
    ms2 = rho * ms + (1 - rho) * np.asarray(g["w"]) ** 2
    expect2 = np.asarray(p1["w"]) - lr * np.asarray(g["w"]) / (np.sqrt(ms2) + eps)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect2, rtol=1e-6)
    assert int(s2["step"]) == 2


def test_sgd_plain_and_momentum():
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 0.5)}
    opt = sgd(0.2)
    p1, _ = opt.update(p, opt.init(p), g)
    np.testing.assert_allclose(np.asarray(p1["w"]), 0.9, rtol=1e-6)

    optm = sgd(0.2, momentum=0.9)
    s = optm.init(p)
    p1, s = optm.update(p, s, g)
    p2, s = optm.update(p1, s, g)
    # mu1 = .5, mu2 = .95 -> w = 1 - .2*.5 - .2*.95
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - 0.2 * 0.5 - 0.2 * 0.95,
                               rtol=1e-6)


def test_adamw_decoupled_decay():
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}           # zero grad: only decay acts
    opt = adamw(0.1, weight_decay=0.5)
    p1, _ = opt.update(p, opt.init(p), g)
    np.testing.assert_allclose(np.asarray(p1["w"]), 10.0 - 0.1 * 0.5 * 10.0,
                               rtol=1e-6)


def test_optimizers_preserve_dtype_and_tree():
    from repro.optim import make
    p = {"a": jnp.ones((2, 3), jnp.bfloat16), "b": {"c": jnp.ones(4)}}
    g = jax.tree.map(jnp.ones_like, p)
    for name in ("rmsprop", "sgd", "adamw"):
        opt = make(name, 1e-2)
        p1, s1 = opt.update(p, opt.init(p), g)
        assert jax.tree.structure(p1) == jax.tree.structure(p)
        assert p1["a"].dtype == jnp.bfloat16
