"""The loop-aware HLO cost analyzer against programs with KNOWN costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_scaled_by_trip_count():
    N, T = 128, 8

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=T)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                        jax.ShapeDtypeStruct((N, N), jnp.float32))
    res = hlo_cost.analyze(txt)
    expect = T * 2 * N ** 3
    assert res["flops"] == pytest.approx(expect, rel=0.01), \
        (res["flops"], expect)
    assert any(t == T for _, t in res["while_loops"])


def test_nested_scan_multiplies():
    N, T1, T2 = 64, 3, 5

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=T2)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=T1)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                        jax.ShapeDtypeStruct((N, N), jnp.float32))
    res = hlo_cost.analyze(txt)
    expect = T1 * T2 * 2 * N ** 3
    assert res["flops"] == pytest.approx(expect, rel=0.01)


def test_unrolled_matches_xla_cost_analysis():
    N = 96

    def f(x, w):
        for _ in range(4):
            x = x @ w
        return x

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((N, N), jnp.float32),
                               jax.ShapeDtypeStruct((N, N), jnp.float32))
    compiled = lowered.compile()
    ours = hlo_cost.analyze(compiled.as_text())["flops"]
    xla = float(hlo_cost.xla_cost_analysis(compiled).get("flops", 0))
    assert ours == pytest.approx(xla, rel=0.01) == pytest.approx(
        4 * 2 * N ** 3, rel=0.01)


def test_dot_general_batched_flops():
    B, M, K, N = 4, 32, 48, 16

    def f(a, b):
        return jnp.einsum("bmk,bkn->bmn", a, b)

    txt = _compile_text(f, jax.ShapeDtypeStruct((B, M, K), jnp.float32),
                        jax.ShapeDtypeStruct((B, K, N), jnp.float32))
    res = hlo_cost.analyze(txt)
    assert res["flops"] == pytest.approx(2 * B * M * K * N, rel=0.01)


def test_collective_bytes_counted_with_loop_scaling():
    """Hand-written module: an all-reduce inside a trip-8 while loop."""
    txt = """
HloModule test

%body (p: (s32[], f32[64,4])) -> (s32[], f32[64,4]) {
  %p = (s32[], f32[64,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,4] get-tuple-element(%p), index=1
  %ar = f32[64,4] all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,4]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[64,4])) -> pred[] {
  %p = (s32[], f32[64,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,4]) -> f32[64,4] {
  %a = f32[64,4] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[64,4]) tuple(%z, %a)
  %w = (s32[], f32[64,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[64,4] get-tuple-element(%w), index=1
}
"""
    res = hlo_cost.analyze(txt)
    assert res["collective_bytes"]["all-reduce"] == 8 * 64 * 4 * 4
    assert res["collective_bytes"]["total"] == 8 * 64 * 4 * 4


def test_shape_bytes_parser():
    assert hlo_cost.shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert hlo_cost.shape_bytes("bf16[10]") == 20
    assert hlo_cost.shape_bytes("(f32[4], s32[2])") == 24
    assert hlo_cost.shape_bytes("pred[]") == 1   # scalars: dims product = 1
