"""Event-driven coordination: subscriptions replace polling.

Three contracts from the issue:
(a) an event-driven simulation processes ZERO poll events,
(b) polling and subscription modes are semantically identical — same task
    counts and final model version on a fixed scenario (including churn and
    heterogeneous speeds),
(c) subscriptions are churn-safe — a wake delivered to a volunteer that has
    left requeues its leases and passes the wake on, so no event is lost and
    the run still completes.

Plus: the sharded QueueServer federation is semantics-invisible, for both the
timing Simulator and the REAL Coordinator (bit-identical model).
"""
from __future__ import annotations

import math

import pytest

from repro.core.simulator import (CostModel, Simulator, SimResult,
                                  SyntheticProblem, VolunteerSpec)


def _cost():
    return CostModel(flops_per_sec=2.0e9, latency=0.020, bandwidth=12.5e6,
                     poll_interval=0.200, cache_bytes=1e12)


def _problem():
    return SyntheticProblem(n_versions=6, n_mb=8, model_bytes=1.0e6,
                            grad_bytes=2.0e5, map_flops=1.0e9,
                            reduce_flops=2.0e7)


def _specs(n=6, churn=False):
    specs = []
    for i in range(n):
        specs.append(VolunteerSpec(
            f"v{i:02d}", speed=0.6 + 0.25 * i,
            join_time=0.0 if i % 3 else 0.5 * i,
            leave_time=25.0 + 4.0 * i if (churn and i % 2 == 0) else math.inf))
    return specs


def _run(mode, *, churn=False, n_shards=1):
    sim = Simulator(_problem(), _specs(churn=churn), cost=_cost(), mode=mode,
                    visibility_timeout=1e9, n_shards=n_shards)
    return sim.run()


def test_event_mode_has_zero_poll_events():
    res = _run("event")
    assert res.final_version == 6
    assert res.poll_events == 0
    assert res.mode == "event"


def test_poll_mode_still_polls():
    res = _run("poll")
    assert res.final_version == 6
    assert res.poll_events > 0
    assert res.mode == "poll"


@pytest.mark.parametrize("churn", [False, True])
def test_modes_agree_on_tasks_and_version(churn):
    ev = _run("event", churn=churn)
    po = _run("poll", churn=churn)
    assert ev.final_version == po.final_version == 6
    n_tasks = 6 * (8 + 1)            # n_versions x (n_mb maps + 1 reduce)
    assert sum(ev.tasks_by_worker.values()) == n_tasks
    assert sum(po.tasks_by_worker.values()) == n_tasks
    # event mode does strictly less bookkeeping work for the same semantics
    assert ev.events < po.events


def test_event_mode_far_fewer_events_than_polling():
    """With volunteers >> tasks (the 10k-browser regime scaled down), polling
    burns events on every idle waiter while subscriptions stay silent."""
    problem = SyntheticProblem(n_versions=12, n_mb=8, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=1.0e9,
                               reduce_flops=2.0e7)
    specs = [VolunteerSpec(f"v{i:03d}", speed=0.7 + (i % 7) * 0.2)
             for i in range(300)]
    results = {}
    for mode in ("event", "poll"):
        results[mode] = Simulator(problem, specs, cost=_cost(), mode=mode,
                                  visibility_timeout=1e9).run()
    ev, po = results["event"], results["poll"]
    assert ev.final_version == po.final_version == 12
    assert sum(ev.tasks_by_worker.values()) == \
        sum(po.tasks_by_worker.values()) == 12 * 9
    assert ev.events * 10 <= po.events, (ev.events, po.events)


def test_subscription_survives_churn_of_woken_consumer():
    """(c) volunteers leave while subscribed or while holding leases: the wake
    is passed on (requeue/kick) and the remaining volunteers finish the run."""
    problem = _problem()
    specs = [
        # v00 grabs tasks early, then leaves mid-run while holding a lease
        VolunteerSpec("v00", speed=2.0, leave_time=6.0),
        # v01 joins at once but is slow: it spends time subscribed/waiting
        VolunteerSpec("v01", speed=0.5),
        # v02 leaves so early it mostly exists as a dangling subscription
        VolunteerSpec("v02", speed=1.0, leave_time=1.0),
        VolunteerSpec("v03", speed=1.0, join_time=10.0),
    ]
    res = Simulator(problem, specs, cost=_cost(), mode="event",
                    visibility_timeout=1e9).run()
    assert res.final_version == 6
    assert res.poll_events == 0
    assert sum(res.tasks_by_worker.values()) == 6 * 9
    # the departed volunteers' leases were requeued and re-executed by others
    assert res.requeues >= 1
    assert "v00" not in res.tasks_by_worker or res.tasks_by_worker.get(
        "v03", 0) > 0


def test_expiry_scans_skipped_when_nothing_can_expire():
    """Regression (ISSUE 2): the run loop used to call expire_all on EVERY
    event — O(all queues x events). It must now consult next_deadline() and
    skip the sweep entirely while no visibility deadline has passed."""
    res = _run("event", churn=True)
    assert res.final_version == 6
    assert res.events > 50                     # plenty of events processed...
    assert res.expire_scans == 0               # ...but zero expiry sweeps


def test_expiry_scans_stay_o_of_expired():
    """With a tight visibility timeout every sweep must pay for itself: a scan
    only happens when >= 1 lease has actually expired (checked against the
    expiry-specific counter, not total requeues, which include barrier nacks)."""
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=2.0e5, map_flops=1.0e9,
                               reduce_flops=2.0e7)
    specs = [VolunteerSpec(f"v{i:02d}", speed=0.8 + 0.2 * i) for i in range(5)]
    sim = Simulator(problem, specs, cost=_cost(), mode="event",
                    visibility_timeout=0.5)
    res = sim.run()
    assert res.final_version == 4
    assert res.expire_scans > 0                # timeouts actually fired
    assert res.expire_scans <= sim.expired     # every scan expired >= 1 lease
    assert res.expire_scans < res.events / 4   # nowhere near one per event


def test_sharded_federation_matches_single_server_simulation():
    single = _run("event", churn=True, n_shards=1)
    sharded = _run("event", churn=True, n_shards=4)
    assert sharded.final_version == single.final_version == 6
    assert sum(sharded.tasks_by_worker.values()) == \
        sum(single.tasks_by_worker.values())
    assert sharded.makespan == pytest.approx(single.makespan)


def test_coordinator_event_driven_and_sharded_bitmatch_sequential():
    """The REAL coordinator on the same subscription primitives (and on a
    4-shard federation) still reproduces the paper's exact-equality claim."""
    jax = pytest.importorskip("jax")
    import numpy as np
    from repro.configs.paper_lstm import TrainParams
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import TrainingProblem, sequential_accumulated
    from repro.data.text import synthetic_corpus

    tp = TrainParams(batch_size=8, examples_per_epoch=32, num_epochs=1,
                     sample_len=16, mini_batch_size=4,
                     mini_batches_to_accumulate=2)
    prob = TrainingProblem.paper_problem(corpus=synthetic_corpus(3000), tp=tp)
    seq_params, _, _ = sequential_accumulated(prob)

    def bitmatch(a, b):
        return all(bool((np.asarray(x) == np.asarray(y)).all())
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    churn = [(2, "leave", "w0"), (5, "join", "w7")]
    res = Coordinator(prob, n_workers=3, churn=churn).run()
    assert bitmatch(res.params, seq_params)
    # the sharded run additionally reshards the federation LIVE mid-training
    # (elastic join + leave) — the rebalance must be invisible to the protocol
    shard_churn = churn + [(3, "add_shard", 0), (6, "remove_shard", 1)]
    res_shard = Coordinator(prob, n_workers=3, churn=shard_churn,
                            n_shards=4).run()
    assert bitmatch(res_shard.params, seq_params)
    assert res_shard.final_version == res.final_version == prob.n_versions
