"""Queue-server semantics: the paper's fault-tolerance claims as invariants.

Property (hypothesis): under ANY interleaving of publish/lease/ack/nack/
expire/drop-consumer, no message is lost and no message is acked twice —
every published message is eventually either pending, in flight, or acked
exactly once ("tasks are not removed from the queue until an ACK").
"""
from __future__ import annotations

import math

from hypothesis import given, settings, strategies as st

from repro.core.queue import Queue, QueueServer


def test_lease_ack_basic():
    q = Queue("q")
    q.publish("a")
    q.publish("b")
    tag, body = q.lease("w0", now=0.0)
    assert body == "a" and q.depth == 1 and q.in_flight == 1
    assert q.ack(tag)
    assert not q.ack(tag)          # double-ack is rejected
    assert q.acked == 1


def test_visibility_timeout_requeues():
    q = Queue("q", default_timeout=10.0)
    q.publish("a")
    tag, _ = q.lease("w0", now=0.0)
    assert q.expire(now=5.0) == 0          # not yet
    assert q.expire(now=10.0) == 1         # deadline hit -> requeued
    assert q.depth == 1 and q.in_flight == 0
    assert not q.ack(tag)                  # stale tag can't ack
    tag2, body = q.lease("w1", now=11.0)
    assert body == "a"


def test_drop_consumer_requeues_everything():
    q = Queue("q")
    for i in range(3):
        q.publish(i)
    q.lease("w0", 0.0)
    q.lease("w0", 0.0)
    q.lease("w1", 0.0)
    assert q.drop_consumer("w0") == 2
    assert q.depth == 2 and q.in_flight == 1


def test_nack_front_preserves_order():
    q = Queue("q")
    q.publish("a")
    q.publish("b")
    tag, body = q.lease("w0", 0.0)
    q.nack(tag, front=True)
    _, body2 = q.lease("w1", 0.0)
    assert body2 == "a"


@st.composite
def _script(draw):
    n_msgs = draw(st.integers(1, 12))
    ops = draw(st.lists(st.tuples(
        st.sampled_from(["lease", "ack", "nack", "expire", "drop"]),
        st.integers(0, 3),          # worker id
        st.floats(0, 100)),          # time
        min_size=1, max_size=60))
    return n_msgs, ops


@given(_script())
@settings(max_examples=200, deadline=None)
def test_no_loss_no_double_completion(script):
    n_msgs, ops = script
    q = Queue("q", default_timeout=15.0)
    for i in range(n_msgs):
        q.publish(i)
    held = {}                                      # worker -> [(tag, body)]
    acked = []
    for op, w, t in ops:
        wid = f"w{w}"
        if op == "lease":
            got = q.lease(wid, now=t)
            if got:
                held.setdefault(wid, []).append(got)
        elif op == "ack" and held.get(wid):
            tag, body = held[wid].pop()
            if q.ack(tag):
                acked.append(body)
        elif op == "nack" and held.get(wid):
            tag, _ = held[wid].pop()
            q.nack(tag)
        elif op == "expire":
            q.expire(now=t)
            # any tag may now be stale; conservatively flush local holds
        elif op == "drop":
            q.drop_consumer(wid)
            held.pop(wid, None)
    # conservation: every message is acked at most once, and everything not
    # acked is still recoverable from the queue (pending or in flight)
    assert len(acked) == len(set(acked))
    assert len(acked) + q.depth + q.in_flight >= n_msgs
    assert q.acked == len(acked)


def test_queueserver_namespaces():
    qs = QueueServer()
    qs.publish("a", 1)
    qs.publish("b", 2)
    assert qs.depth("a") == 1 and qs.depth("b") == 1
    got = qs.lease("a", "w0", 0.0)
    assert got and got[1] == 1
    assert not qs.drained()
    qs.ack("a", got[0])
    got = qs.lease("b", "w0", 0.0)
    qs.ack("b", got[0])
    assert qs.drained()
