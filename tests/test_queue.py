"""Queue-server semantics: the paper's fault-tolerance claims as invariants.

Property (hypothesis, when installed): under ANY interleaving of publish/lease/
ack/nack/expire/drop-consumer, no message is lost and no message is acked
twice — every published message is eventually either pending, in flight, or
acked exactly once ("tasks are not removed from the queue until an ACK").
The same invariant also runs as a plain seeded-random test so the suite does
not depend on hypothesis.
"""
from __future__ import annotations

import math
import random

import pytest

from repro.core.queue import Queue, QueueServer, ShardedQueueServer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def test_lease_ack_basic():
    q = Queue("q")
    q.publish("a")
    q.publish("b")
    tag, body = q.lease("w0", now=0.0)
    assert body == "a" and q.depth == 1 and q.in_flight == 1
    assert q.ack(tag)
    assert not q.ack(tag)          # double-ack is rejected
    assert q.acked == 1


def test_visibility_timeout_requeues():
    q = Queue("q", default_timeout=10.0)
    q.publish("a")
    tag, _ = q.lease("w0", now=0.0)
    assert q.expire(now=5.0) == 0          # not yet
    assert q.expire(now=10.0) == 1         # deadline hit -> requeued
    assert q.depth == 1 and q.in_flight == 0
    assert not q.ack(tag)                  # stale tag can't ack
    tag2, body = q.lease("w1", now=11.0)
    assert body == "a"


def test_drop_consumer_requeues_everything():
    q = Queue("q")
    for i in range(3):
        q.publish(i)
    q.lease("w0", 0.0)
    q.lease("w0", 0.0)
    q.lease("w1", 0.0)
    assert q.drop_consumer("w0") == 2
    assert q.depth == 2 and q.in_flight == 1


def test_nack_front_preserves_order():
    q = Queue("q")
    q.publish("a")
    q.publish("b")
    tag, body = q.lease("w0", 0.0)
    q.nack(tag, front=True)
    _, body2 = q.lease("w1", 0.0)
    assert body2 == "a"


def test_next_deadline_tracks_releases():
    q = Queue("q", default_timeout=10.0)
    q.publish("a")
    q.publish("b")
    t1, _ = q.lease("w0", now=0.0)
    t2, _ = q.lease("w1", now=3.0)
    assert q.next_deadline() == 10.0
    q.ack(t1)
    assert q.next_deadline() == 13.0       # stale heap entry skipped
    q.ack(t2)
    assert q.next_deadline() is None


# ---------------------------------------------------------------------------
# subscriptions (event-driven waits)
# ---------------------------------------------------------------------------

def test_subscribe_woken_by_publish():
    q = Queue("q")
    woken = []
    q.subscribe("w0", lambda: woken.append("w0"))
    q.subscribe("w1", lambda: woken.append("w1"))
    q.publish("a")
    assert woken == ["w0"]                 # exactly one waiter per event, FIFO
    q.publish("b")
    assert woken == ["w0", "w1"]


def test_subscribe_woken_by_requeue():
    q = Queue("q")
    q.publish("a")
    tag, _ = q.lease("w0", 0.0)
    woken = []
    q.subscribe("w1", lambda: woken.append("w1"))
    q.nack(tag)
    assert woken == ["w1"]


def test_subscribe_fires_immediately_after_missed_event():
    q = Queue("q")
    q.publish("a")                         # nobody waiting -> signal banked
    woken = []
    q.subscribe("w0", lambda: woken.append("w0"))
    assert woken == ["w0"]                 # no lost wakeup
    q.subscribe("w1", lambda: woken.append("w1"))
    assert woken == ["w0"]                 # signal consumed once


def test_publish_kind_ignores_requeues():
    q = Queue("q")
    q.publish("a")
    tag, _ = q.lease("w0", 0.0)
    woken = []
    # the earlier publish was banked: first subscribe fires immediately
    q.subscribe("barrier", lambda: woken.append("banked"), kind="publish")
    assert woken == ["banked"]
    q.subscribe("barrier", lambda: woken.append("pub"), kind="publish")
    q.nack(tag)                            # requeue must NOT wake the barrier
    assert woken == ["banked"]
    q.publish("b")
    assert woken == ["banked", "pub"]


def test_unsubscribe_and_kick_pass_wake_to_next_waiter():
    q = Queue("q")
    woken = []
    q.subscribe("gone", lambda: woken.append("gone"))
    q.subscribe("w1", lambda: woken.append("w1"))
    assert q.unsubscribe("gone") == 1
    q.publish("a")
    assert woken == ["w1"]
    # a consumed wake handed back via kick reaches the next waiter
    q.subscribe("w2", lambda: woken.append("w2"))
    q.kick()
    assert woken == ["w1", "w2"]


def test_kick_with_zero_waiters_banks_the_signal():
    """A kick with nobody waiting must not vanish: it banks the signal so the
    next subscriber fires immediately (the wake a departed volunteer consumed
    is handed to whoever subscribes next)."""
    q = Queue("q")
    q.kick()                                   # no waiters registered
    woken = []
    q.subscribe("w0", lambda: woken.append("w0"))
    assert woken == ["w0"]                     # banked kick delivered
    q.subscribe("w1", lambda: woken.append("w1"))
    assert woken == ["w0"]                     # consumed exactly once


def test_unsubscribe_removes_both_any_and_publish_waiters():
    q = Queue("q")
    woken = []
    q.subscribe("dual", lambda: woken.append("any"), kind="any")
    q.subscribe("dual", lambda: woken.append("pub"), kind="publish")
    q.subscribe("other", lambda: woken.append("other-any"))
    assert q.waiters == 3
    assert q.unsubscribe("dual") == 2          # both kinds removed at once
    assert q.waiters == 1
    q.publish("a")                             # only the survivor wakes
    assert woken == ["other-any"]


def test_nack_back_goes_behind_existing_pending():
    q = Queue("q")
    q.publish("a")
    q.publish("b")
    tag, body = q.lease("w0", 0.0)
    assert body == "a"
    q.nack(tag, front=False)                   # voluntary give-back to the END
    assert q.peek_all() == ["b", "a"]
    _, first = q.lease("w1", 0.0)
    assert first == "b"
    _, second = q.lease("w1", 0.0)
    assert second == "a"
    assert q.requeued == 1


def test_queueserver_namespaces():
    qs = QueueServer()
    qs.publish("a", 1)
    qs.publish("b", 2)
    assert qs.depth("a") == 1 and qs.depth("b") == 1
    got = qs.lease("a", "w0", 0.0)
    assert got and got[1] == 1
    assert not qs.drained()
    qs.ack("a", got[0])
    got = qs.lease("b", "w0", 0.0)
    qs.ack("b", got[0])
    assert qs.drained()


# ---------------------------------------------------------------------------
# sharded federation (consistent-hash routing)
# ---------------------------------------------------------------------------

def test_sharded_routing_is_stable_and_total():
    fed = ShardedQueueServer(4)
    names = [f"map-results:v{i}" for i in range(64)] + ["initial"]
    first = {n: fed.shard_of(n) for n in names}
    for n in names:                        # deterministic routing
        assert fed.shard_of(n) == first[n]
        assert 0 <= first[n] < 4
    # the ring must actually spread queues over shards
    fed2 = ShardedQueueServer(4)
    for n in names:
        fed2.declare(n)
    loads = fed2.shard_loads()
    assert sum(loads) == len(names)
    assert sum(1 for l in loads if l > 0) >= 3, loads


def test_sharded_consistent_hash_minimal_remap():
    a = ShardedQueueServer(4)
    b = ShardedQueueServer(5)              # one shard added
    names = [f"q{i}" for i in range(400)]
    moved = sum(1 for n in names if a.shard_of(n) != b.shard_of(n))
    # consistent hashing: ~1/K of keys remap, far from all of them
    assert moved < len(names) * 0.5, moved


def test_sharded_same_semantics_as_single_server():
    single, fed = QueueServer(), ShardedQueueServer(3)
    for qs in (single, fed):
        for i in range(5):
            qs.publish("tasks", i)
        got = qs.lease("tasks", "w0", 0.0)
        assert got[1] == 0
        qs.nack("tasks", got[0])
        got2 = qs.lease("tasks", "w0", 0.0)
        assert got2[1] == 0                # nack-to-front preserved
        qs.ack("tasks", got2[0])
        assert qs.depth("tasks") == 4
        assert qs.drop_consumer("w0") == 0
        assert not qs.drained(["tasks"])
    assert fed.total_requeued == single.total_requeued == 1


def test_sharded_subscribe_and_expire():
    fed = ShardedQueueServer(3, default_timeout=10.0)
    woken = []
    fed.subscribe("tasks", "w0", lambda: woken.append("w0"))
    fed.publish("tasks", "a")
    assert woken == ["w0"]
    tag, _ = fed.lease("tasks", "w1", 0.0)
    assert fed.next_deadline() == 10.0
    assert fed.expire_all(10.0) == 1
    assert fed.depth("tasks") == 1


# ---------------------------------------------------------------------------
# no-loss / no-double-ack invariant: plain seeded port of the property test
# ---------------------------------------------------------------------------

def _run_script(n_msgs, ops):
    q = Queue("q", default_timeout=15.0)
    for i in range(n_msgs):
        q.publish(i)
    held = {}                                      # worker -> [(tag, body)]
    acked = []
    for op, w, t in ops:
        wid = f"w{w}"
        if op == "lease":
            got = q.lease(wid, now=t)
            if got:
                held.setdefault(wid, []).append(got)
        elif op == "ack" and held.get(wid):
            tag, body = held[wid].pop()
            if q.ack(tag):
                acked.append(body)
        elif op == "nack" and held.get(wid):
            tag, _ = held[wid].pop()
            q.nack(tag)
        elif op == "expire":
            q.expire(now=t)
            # any tag may now be stale; conservatively flush local holds
        elif op == "drop":
            q.drop_consumer(wid)
            held.pop(wid, None)
    # conservation: every message is acked at most once, and everything not
    # acked is still recoverable from the queue (pending or in flight)
    assert len(acked) == len(set(acked))
    assert len(acked) + q.depth + q.in_flight >= n_msgs
    assert q.acked == len(acked)


def test_extend_lease_postpones_expiry():
    """ExtendLease semantics: a heartbeat re-stamps the deadline, so a live
    consumer's lease survives past the original visibility timeout while an
    un-renewed one expires on schedule."""
    qs = QueueServer(default_timeout=1.0)
    qs.publish("q", "live")
    qs.publish("q", "dead")
    t_live, _ = qs.lease("q", "alive", now=0.0)
    t_dead, _ = qs.lease("q", "gone", now=0.0)
    assert qs.extend("q", t_live, now=0.9)         # heartbeat at 0.9
    assert qs.expire_all(1.5) == 1                 # only the silent one
    q = qs.queues["q"]
    assert t_live in q._in_flight and t_dead not in q._in_flight
    assert qs.next_deadline() == 1.9               # renewed deadline is live
    q.check_invariants()
    # renewing an expired (requeued) lease loses the race
    assert not qs.extend("q", t_dead, now=1.6)
    # ... and an extended deadline itself eventually expires
    assert qs.expire_all(2.0) == 1
    assert q.in_flight == 0


def test_extend_lease_receipt_check():
    """A zombie whose lease expired and was re-granted to another consumer
    must NOT be able to renew (and must learn it lost) — SQS receipt-handle
    semantics."""
    qs = QueueServer(default_timeout=1.0)
    qs.publish("q", "x")
    tag_a, _ = qs.lease("q", "A", now=0.0)
    qs.expire_all(2.0)                             # A stalls; lease requeues
    tag_b, _ = qs.lease("q", "B", now=2.0)
    assert tag_b == tag_a                          # same message, same tag
    assert not qs.extend("q", tag_a, now=2.5, consumer="A")   # zombie told no
    assert qs.extend("q", tag_b, now=2.5, consumer="B")       # holder renews
    assert qs.queues["q"]._in_flight[tag_b].deadline == 3.5
    # consumer-blind extend (no receipt) keeps the old permissive behavior
    assert qs.extend("q", tag_b, now=3.0)


def test_extend_lease_with_explicit_timeout_and_snapshot():
    qs = QueueServer(default_timeout=5.0)
    qs.publish("q", "x")
    tag, _ = qs.lease("q", "w0", now=0.0)
    qs.extend("q", tag, now=1.0, timeout=100.0)
    fresh = QueueServer()
    fresh.restore(qs.snapshot())                   # renewal rides the snapshot
    assert fresh.next_deadline() == 101.0
    assert fresh.expire_all(50.0) == 0
    assert fresh.expire_all(102.0) == 1


@pytest.mark.parametrize("seed", range(25))
def test_no_loss_no_double_completion_seeded(seed):
    rng = random.Random(seed)
    n_msgs = rng.randint(1, 12)
    ops = [(rng.choice(["lease", "ack", "nack", "expire", "drop"]),
            rng.randint(0, 3), rng.uniform(0, 100))
           for _ in range(rng.randint(1, 60))]
    _run_script(n_msgs, ops)


if HAVE_HYPOTHESIS:
    @st.composite
    def _script(draw):
        n_msgs = draw(st.integers(1, 12))
        ops = draw(st.lists(st.tuples(
            st.sampled_from(["lease", "ack", "nack", "expire", "drop"]),
            st.integers(0, 3),          # worker id
            st.floats(0, 100)),          # time
            min_size=1, max_size=60))
        return n_msgs, ops

    @given(_script())
    @settings(max_examples=200, deadline=None)
    def test_no_loss_no_double_completion(script):
        n_msgs, ops = script
        _run_script(n_msgs, ops)
