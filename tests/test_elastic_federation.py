"""Elastic queue federation: live shard join/leave with full-state migration.

``ShardedQueueServer.add_shard()`` / ``remove_shard(i)`` recompute the
consistent-hash ring and migrate every remapped queue's COMPLETE live state —
pending FIFO, in-flight table with deadlines (re-indexed at the destination),
banked signals, registered waiters, tag counter, stats counters — so a
rebalance is invisible to consumers except that ~1/K of names change owner
(the bound is asserted below). The property test drives a single QueueServer
and a federation through identical random op sequences — including membership
changes — and asserts observational equivalence op by op and state by state.
"""
from __future__ import annotations

import random

import pytest

from repro.core.queue import Queue, QueueServer, ShardedQueueServer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _loaded_federation(k: int, n_names: int, **kw) -> ShardedQueueServer:
    fed = ShardedQueueServer(k, **kw)
    for i in range(n_names):
        fed.publish(f"queue-{i}", i)
    return fed


# ---------------------------------------------------------------------------
# the ~1/K remap bound (deterministic: blake2b ring, fixed vnodes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 3, 4, 5, 8])
def test_add_shard_remaps_at_most_1_5_over_k(k):
    n = 600
    fed = _loaded_federation(k, n)
    moved = fed.add_shard()
    assert 0 < len(moved) <= 1.5 * n / (k + 1), (k, len(moved))
    # every migrated queue now lives on (and routes to) the new shard
    for name in moved:
        assert fed.shard_of(name) == k
        assert name in fed.shards[k].queues
    # nothing was lost federation-wide
    assert sum(fed.shard_loads()) == n


@pytest.mark.parametrize("k,idx", [(3, 0), (3, 2), (5, 1), (5, 4), (8, 6)])
def test_remove_shard_remaps_at_most_1_5_over_k(k, idx):
    n = 600
    fed = _loaded_federation(k, n)
    moved = fed.remove_shard(idx)
    assert 0 < len(moved) <= 1.5 * n / k, (k, idx, len(moved))
    assert len(fed.shards) == k - 1
    assert sum(fed.shard_loads()) == n          # zero queues lost
    for name in moved:                          # re-routed consistently
        assert name in fed.shards[fed.shard_of(name)].queues


def test_remove_last_shard_raises():
    fed = ShardedQueueServer(1)
    with pytest.raises(ValueError):
        fed.remove_shard(0)


def test_ring_stable_for_surviving_shards():
    """A membership change must not reshuffle names between SURVIVING shards:
    only names owned by (or claimed by) the changed member move."""
    n = 500
    names = [f"queue-{i}" for i in range(n)]
    fed = _loaded_federation(4, n)
    before = {nm: fed.shard_of(nm) for nm in names}
    moved = set(fed.add_shard())
    for nm in names:
        if nm not in moved:
            assert fed.shard_of(nm) == before[nm]
    before = {nm: fed.shard_of(nm) for nm in names}
    sids_before = list(fed._sids)
    moved = set(fed.remove_shard(2))
    for nm in names:
        if nm not in moved:
            assert fed._sids[fed.shard_of(nm)] == sids_before[before[nm]]


# ---------------------------------------------------------------------------
# migration carries the FULL live state
# ---------------------------------------------------------------------------

def test_migration_preserves_pending_fifo_and_tag_counter():
    fed = ShardedQueueServer(2)
    for i in range(50):
        for body in ("a", "b", "c"):
            fed.publish(f"q{i}", f"{i}-{body}")
    moved = fed.add_shard()
    assert moved
    name = moved[0]
    got1 = fed.lease(name, "w0", 0.0)
    got2 = fed.lease(name, "w0", 0.0)
    i = name[1:]
    assert (got1[1], got2[1]) == (f"{i}-a", f"{i}-b")   # FIFO preserved
    assert got2[0] == got1[0] + 1                        # tag order intact
    new_tag = fed.publish(name, f"{i}-d")
    assert new_tag == 3                                  # counter migrated too
    q = fed.queues[name]
    assert q.published == 4 and q.acked == 0


def test_migration_preserves_in_flight_deadlines():
    """In-flight messages migrate WITH their visibility deadlines, re-indexed
    in the destination shard's deadline heap — expiry keeps working."""
    fed = ShardedQueueServer(2, default_timeout=7.0)
    n = 40
    for i in range(n):
        fed.publish(f"q{i}", i)
        fed.lease(f"q{i}", "holder", now=0.0)
    assert fed.next_deadline() == 7.0
    moved = fed.add_shard()
    assert moved
    assert fed.next_deadline() == 7.0          # index survived the handoff
    assert fed.expire_all(6.9) == 0
    assert fed.expire_all(7.0) == n            # every lease expires on time
    for i in range(n):
        assert fed.depth(f"q{i}") == 1         # ...and is pending again
    assert fed.next_deadline() is None


def test_migration_preserves_waiters_and_banked_signals():
    fed = ShardedQueueServer(2)
    woken = {}
    for i in range(30):
        name = f"q{i}"
        fed.publish(name, "seed")              # banks "any" + publish signals
        fed.subscribe(name, "s0", lambda n=name: woken.setdefault(n, []).append("s0"))
        # s0 consumed the banked any-signal; s1 becomes a REGISTERED waiter
        fed.subscribe(name, "s1", lambda n=name: woken.setdefault(n, []).append("s1"))
    moved = fed.add_shard()
    assert moved
    name = moved[0]
    assert woken[name] == ["s0"]
    fed.publish(name, "after-move")            # must wake the migrated waiter
    assert woken[name] == ["s0", "s1"]
    # the publish-kind signal banked before migration also survived
    fed2_woken = []
    fed.subscribe(name, "b", lambda: fed2_woken.append("pub"), kind="publish")
    assert fed2_woken == ["pub"]


def test_remove_shard_zero_loss_census():
    from repro.core.chaos import federation_census

    fed = ShardedQueueServer(4, default_timeout=9.0)
    for i in range(120):
        fed.publish(f"q{i}", f"{i}-a")
        fed.publish(f"q{i}", f"{i}-b")
        if i % 3 == 0:
            fed.lease(f"q{i}", "w0", now=0.0)

    before = federation_census(fed)
    for idx in (2, 0, 1):                      # shrink 4 -> 1, step by step
        fed.remove_shard(idx)
        # bit-equal live state each time: pending bodies in order AND the
        # full in-flight table (tag, consumer, deadline, body)
        assert federation_census(fed) == before
    assert len(fed.shards) == 1
    assert fed.expire_all(9.0) == 40           # deadlines all survived 3 hops


def test_queue_check_invariants_catches_violations():
    q = Queue("q", default_timeout=5.0)
    q.publish("a")
    tag, _ = q.lease("w0", now=0.0)
    q.check_invariants()                       # healthy state passes
    q._deadlines.clear()                       # corrupt: uncovered deadline
    with pytest.raises(AssertionError):
        q.check_invariants()
    q._deadlines.append((5.0, tag))            # repair for teardown check
    q2 = Queue("q2")
    q2.publish("a")
    entry = q2._pending.popleft()              # corrupt: message vanished
    with pytest.raises(AssertionError):
        q2.check_invariants()
    q2._pending.append(entry)                  # repair for teardown check


# ---------------------------------------------------------------------------
# observational equivalence: single server vs elastic federation under random
# op sequences (publish/lease/ack/nack/expire/drop/add_shard/remove_shard).
# Plain seeded port always runs; the hypothesis version widens the search.
# ---------------------------------------------------------------------------

_EQ_OPS = ("publish", "lease", "ack", "nack", "expire", "drop",
           "add_shard", "remove_shard")


def _run_equivalence_script(ops):
    single = QueueServer(default_timeout=6.0)
    fed = ShardedQueueServer(3, default_timeout=6.0)
    held = []                                  # (qname, tag) — tags match
    now = 0.0
    for op, a, dt in ops:
        now += dt
        qn = f"q{a % 7}"
        wid = f"w{a % 3}"
        if op == "publish":
            assert single.publish(qn, a) == fed.publish(qn, a)
        elif op == "lease":
            g1 = single.lease(qn, wid, now)
            g2 = fed.lease(qn, wid, now)
            assert g1 == g2
            if g1 is not None:
                held.append((qn, g1[0]))
        elif op == "ack" and held:
            hq, tag = held.pop(a % len(held))
            assert single.ack(hq, tag) == fed.ack(hq, tag)
        elif op == "nack" and held:
            hq, tag = held.pop(a % len(held))
            front = bool(a % 2)
            assert single.nack(hq, tag, front=front) == \
                fed.nack(hq, tag, front=front)
        elif op == "expire":
            assert single.expire_all(now) == fed.expire_all(now)
        elif op == "drop":
            assert single.drop_consumer(wid) == fed.drop_consumer(wid)
        elif op == "add_shard":
            if len(fed.shards) < 8:
                fed.add_shard()                # no-op on the single server
        elif op == "remove_shard":
            if len(fed.shards) > 1:
                fed.remove_shard(a % len(fed.shards))
    # end-state observational equivalence
    assert set(single.queues) == set(fed.queues)
    for qn in single.queues:
        q1, q2 = single.queues[qn], fed.queues[qn]
        assert q1.peek_all() == q2.peek_all()              # pending, in order
        assert (q1.published, q1.acked, q1.requeued, q1.depth, q1.in_flight) \
            == (q2.published, q2.acked, q2.requeued, q2.depth, q2.in_flight)
        assert q1.next_deadline() == q2.next_deadline()
    assert single.next_deadline() == fed.next_deadline()
    assert single.drained() == fed.drained()
    assert single.total_requeued == fed.total_requeued


@pytest.mark.parametrize("seed", range(15))
def test_federation_equivalence_seeded(seed):
    rng = random.Random(seed)
    ops = [(rng.choice(_EQ_OPS), rng.randint(0, 40),
            round(rng.uniform(0.0, 3.0), 3))
           for _ in range(rng.randint(10, 120))]
    _run_equivalence_script(ops)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.sampled_from(_EQ_OPS),
                              st.integers(0, 40),
                              st.floats(0.0, 3.0, allow_nan=False)),
                    min_size=1, max_size=120))
    @settings(max_examples=150, deadline=None)
    def test_federation_equivalence_hypothesis(ops):
        _run_equivalence_script(ops)
