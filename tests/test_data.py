"""Data-pipeline determinism — the substrate of the Table-4 invariance."""
import numpy as np

from repro.data.text import CharVocab, TextTask, repo_corpus, synthetic_corpus


def test_vocab_roundtrip():
    text = "hello queue world"
    v = CharVocab.from_text(text)
    assert v.decode(v.encode(text)) == text


def test_schedule_is_pure_function_of_seed():
    t1 = TextTask.build(synthetic_corpus(5000), seed=42)
    t2 = TextTask.build(synthetic_corpus(5000), seed=42)
    np.testing.assert_array_equal(t1.starts(3, 7, 32), t2.starts(3, 7, 32))
    t3 = TextTask.build(synthetic_corpus(5000), seed=43)
    assert not np.array_equal(t1.starts(3, 7, 32), t3.starts(3, 7, 32))


def test_minibatch_slices_the_batch():
    """map-task minibatches re-assemble into exactly the sequential batch."""
    t = TextTask.build(synthetic_corpus(5000), sample_len=20)
    full = t.batch(epoch=1, batch=2, batch_size=16)
    parts = [t.minibatch(1, 2, 16, mb, 4) for mb in range(4)]
    x = np.concatenate([p["x"] for p in parts])
    y = np.concatenate([p["y"] for p in parts])
    np.testing.assert_array_equal(x, full["x"])
    np.testing.assert_array_equal(y, full["y"])


def test_batch_shapes_and_onehot():
    t = TextTask.build(synthetic_corpus(3000), sample_len=15)
    b = t.batch(0, 0, 8)
    V = t.vocab.size
    assert b["x"].shape == (8, 15, V) and b["y"].shape == (8,)
    np.testing.assert_array_equal(b["x"].sum(-1), np.ones((8, 15)))
    assert (b["y"] >= 0).all() and (b["y"] < V).all()


def test_repo_corpus_is_this_repo():
    text = repo_corpus(max_chars=50_000)
    assert len(text) >= 10_000
    assert "def " in text or "import" in text     # it's really source code
