"""Gradient-compression contracts (paper §III/§VI) + hypothesis properties.

The round-trip properties also run as plain parametrized tests so the suite
does not depend on hypothesis being installed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compression as CP

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _tree(key, sizes=(37, 256)):
    ks = jax.random.split(key, len(sizes))
    return {f"w{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def test_topk_keeps_exact_fraction():
    g = _tree(jax.random.PRNGKey(0), (1000,))
    payload, nbytes = CP.topk_encode(g, 0.05)
    dec = CP.topk_decode(payload)
    assert int((dec["w0"] != 0).sum()) == 50
    # the kept entries are the largest |g|
    kept = np.sort(np.abs(np.asarray(g["w0"])))[-50:]
    got = np.sort(np.abs(np.asarray(dec["w0"][dec["w0"] != 0])))
    np.testing.assert_allclose(got, kept)
    assert nbytes == 50 * 8                 # idx int32 + val fp32


def test_ternary_decodes_to_three_levels():
    g = _tree(jax.random.PRNGKey(1))
    payload, nbytes = CP.ternary_encode(g)
    dec = CP.ternary_decode(payload)
    for k in g:
        vals = np.unique(np.asarray(dec[k]))
        s = float(jnp.max(jnp.abs(g[k])))
        assert all(np.isclose(abs(v), 0.0) or np.isclose(abs(v), s, rtol=1e-6)
                   for v in vals)
    dense = CP.dense_bytes(g)
    assert nbytes < dense / 10              # ~16x smaller


def _check_topk_roundtrip(n, frac):
    g = {"w": jax.random.normal(jax.random.PRNGKey(n), (n,))}
    payload, _ = CP.topk_encode(g, frac)
    dec = CP.topk_decode(payload)
    assert dec["w"].shape == (n,)
    k = max(int(np.ceil(frac * n)), 1)
    assert int((dec["w"] != 0).sum()) <= k
    # decoded values are a subset of the original values
    orig = np.asarray(g["w"])
    nz = np.asarray(dec["w"])[np.asarray(dec["w"]) != 0]
    assert all(np.isclose(v, orig).any() for v in nz)


def _check_ternary_error_bounded(n):
    g = {"w": jax.random.normal(jax.random.PRNGKey(n), (n,))}
    payload, _ = CP.ternary_encode(g)
    dec = CP.ternary_decode(payload)
    s = float(jnp.max(jnp.abs(g["w"])))
    # threshold variant: |g - dec| <= s/2 elementwise
    assert float(jnp.max(jnp.abs(g["w"] - dec["w"]))) <= s / 2 + 1e-6


@pytest.mark.parametrize("n,frac", [(1, 1.0), (2, 0.001), (7, 0.5),
                                    (64, 0.1), (333, 0.03), (2000, 0.01)])
def test_topk_roundtrip_parametrized(n, frac):
    _check_topk_roundtrip(n, frac)


@pytest.mark.parametrize("n", [1, 2, 5, 33, 256, 999])
def test_ternary_error_bounded_parametrized(n):
    _check_ternary_error_bounded(n)


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 2000), st.floats(0.001, 1.0))
    @settings(max_examples=50, deadline=None)
    def test_topk_roundtrip_properties(n, frac):
        _check_topk_roundtrip(n, frac)

    @given(st.integers(1, 999))
    @settings(max_examples=30, deadline=None)
    def test_ternary_error_bounded(n):
        _check_ternary_error_bounded(n)


def test_error_feedback_reduces_bias():
    """With EF, the accumulated compressed signal tracks the true sum."""
    T = 60
    codec = CP.make_codec("topk", fraction=0.1)
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (200,))}
    residual = CP.ef_init(g_true)
    acc = jnp.zeros(200)
    acc_noef = jnp.zeros(200)
    for i in range(T):
        dec, residual, _ = CP.ef_compress(codec, g_true, residual)
        acc = acc + dec["w"]
        acc_noef = acc_noef + CP.topk_decode(CP.topk_encode(g_true, 0.1)[0])["w"]
    target = T * g_true["w"]
    rel = float(jnp.linalg.norm(acc - target) / jnp.linalg.norm(target))
    rel_noef = float(jnp.linalg.norm(acc_noef - target)
                     / jnp.linalg.norm(target))
    # EF residual is bounded (~(1/frac-1)|g|) so rel ~ 9/T -> small;
    # without EF the same coordinates are dropped forever -> constant error
    assert rel < 0.2, rel
    assert rel < rel_noef / 3, (rel, rel_noef)


# ---------------------------------------------------------------------------
# compressed gradients on the wire (ISSUE 4 satellite): a topk/ternary
# GradResult payload must survive the byte codec + WireTransport, and the
# MEASURED wire size must feed the Simulator's network cost model
# ---------------------------------------------------------------------------

def _codec_payload(name):
    g = {"lstm": {"wx": jax.random.normal(jax.random.PRNGKey(3), (64, 32)),
                  "b": jax.random.normal(jax.random.PRNGKey(4), (32,))},
         "head": jax.random.normal(jax.random.PRNGKey(5), (32, 8))}
    codec = CP.make_codec(name, fraction=0.05) if name == "topk" \
        else CP.make_codec(name)
    payload, nbytes = codec.encode(g)
    return g, codec, payload, nbytes


@pytest.mark.parametrize("name", ["topk", "ternary"])
def test_compressed_gradresult_roundtrips_encode_message(name):
    from repro.core.protocol import decode_message, encode_message
    from repro.core.tasks import GradResult, results_queue
    from repro.core.protocol import PublishResult
    _, codec, payload, nbytes = _codec_payload(name)
    msg = PublishResult(results_queue(1),
                        GradResult(1, 3, payload, nbytes, 0.5, "w0",
                                   computed_at=1))
    back = decode_message(encode_message(msg))
    r = back.result
    assert (r.version, r.mb_index, r.nbytes, r.computed_at) == (1, 3, nbytes, 1)
    # the decoded payload decompresses to the identical dense gradients
    want = codec.decode(payload)
    got = codec.decode(r.payload)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["topk", "ternary"])
def test_compressed_gradresult_over_wiretransport_feeds_cost_model(name):
    """Publish a compressed GradResult through a real WireTransport, measure
    the envelope, and verify the measured size drives the Simulator's network
    cost model (smaller grads -> fewer simulated bytes AND less time)."""
    from repro.core.dataserver import DataServer
    from repro.core.protocol import PublishResult, ServerEndpoint
    from repro.core.queue import QueueServer
    from repro.core.simulator import (CostModel, Simulator, SyntheticProblem,
                                      VolunteerSpec)
    from repro.core.tasks import GradResult, results_queue
    from repro.core.transport import WireTransport
    g, codec, payload, nbytes = _codec_payload(name)
    dense = CP.dense_bytes(g)
    assert nbytes < dense
    ep = ServerEndpoint(QueueServer(), DataServer())
    wt = WireTransport(ep)
    wt.take_bytes()
    wt.call(PublishResult(results_queue(0),
                          GradResult(0, 0, payload, nbytes, 0.0, "w0",
                                     computed_at=0)))
    measured = wt.take_bytes()
    assert measured > 0
    # the server-side queue actually holds the compressed result
    assert ep.qs.depth(results_queue(0)) == 1
    # feed measured vs dense into the cost model: strictly cheaper on the wire
    problem = SyntheticProblem(n_versions=3, n_mb=4, model_bytes=5.0e5,
                               map_flops=5.0e8)
    specs = [VolunteerSpec(f"v{i}") for i in range(3)]
    cost = CostModel(flops_per_sec=2.0e9, bandwidth=2.0e6, cache_bytes=1e15)

    def run(gb):
        return Simulator(problem, specs, cost=cost, grad_bytes=gb,
                         visibility_timeout=1e9).run()
    small, big = run(measured), run(float(dense))
    assert small.final_version == big.final_version == 3
    assert small.bytes_sent < big.bytes_sent
    assert small.makespan < big.makespan


def test_training_converges_with_ternary_ef():
    """Paper-style training still learns under ternary compression + EF."""
    from repro.configs.paper_lstm import TrainParams
    from repro.core.coordinator import Coordinator
    from repro.core.mapreduce import TrainingProblem
    from repro.data.text import synthetic_corpus
    tp = TrainParams(batch_size=8, examples_per_epoch=64, num_epochs=2,
                     sample_len=16, mini_batch_size=4,
                     mini_batches_to_accumulate=2, learning_rate=0.05)
    prob = TrainingProblem.paper_problem(corpus=synthetic_corpus(4000), tp=tp)
    res = Coordinator(prob, n_workers=2,
                      codec=CP.make_codec("ternary")).run()
    h = len(res.losses) // 2                       # per-version losses: noisy;
    first = float(np.mean(res.losses[:h]))         # compare half-means
    second = float(np.mean(res.losses[h:]))
    assert second < first + 0.05, (first, second)  # it still learns
    res_dense = Coordinator(prob, n_workers=2).run()
    assert res.final_version == res_dense.final_version
