"""Multi-gateway control plane: op-log failover proven as a test tier.

The claims under test, each mapped to a production mechanism:

1. **Op-log replay determinism** — a gateway's durable truth is its op log
   (``cluster_dir``): base + every acknowledged state-changing op. Replaying
   it (``replay_oplog``) must reconstruct the live server's durable surface
   bit-for-bit, whether or not the log rolled epochs mid-run.
2. **Mid-handoff lease expiry** — a lease granted by a gateway that is then
   kill -9'd must survive into the adopter's replayed state and expire there
   on the normal visibility clock, requeueing the ticket.
3. **Cross-gateway nack ordering** — ``Nack(front=True)`` routed over a
   ``Forward`` hop must preserve front-of-queue semantics exactly as a
   local nack would.
4. **Peer adoption of a killed gateway** — in-process (``die()``) and as a
   real SIGKILLed process: the deterministic adopter (smallest live gid)
   replays the victim's log and the run completes at the reference version.
5. **Op-log segmentation** — any interleaving of base snapshots, appends,
   reopens and crash-at-byte-k truncation yields a loadable log whose
   recovered records are exactly the acknowledged-durable prefix
   (property-based: hypothesis when installed, seeded scripts always).
"""
from __future__ import annotations

import json
import os
import random
import signal
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from benchmarks.run import check_bench_records
from repro.core.chaos import (ChaosEvent, ChaosSchedule, ChaosSimulator,
                              _smoke_cost, _smoke_problem, _smoke_specs,
                              gateway_schedule, run_chaos)
from repro.core.elastic import (MODEL_KEY, GatewayRing, OpLog,
                                durable_fingerprint)
from repro.core.gateway import (GatewayServer, SocketTransport, _wait_port,
                                replay_oplog, run_volunteer,
                                run_volunteer_resilient)
from repro.core.protocol import (LatestReq, LeaseGrant, LeaseReq, Nack,
                                 encode_message)
from repro.core.simulator import SyntheticProblem
from repro.core.tasks import INITIAL_QUEUE

POLICY = "sync"
N_VERSIONS, N_MB = 2, 3
N_TASKS = N_VERSIONS * (N_MB + 1)     # sync: n_mb maps + 1 reduce per version


def _problem() -> SyntheticProblem:
    return SyntheticProblem(n_versions=N_VERSIONS, n_mb=N_MB,
                            model_bytes=1.0e4, grad_bytes=1.0e3,
                            map_flops=1.0e6, reduce_flops=1.0e5)


def _cluster(k: int, tmpdir: str, visibility_timeout: float = 2.0):
    servers = [GatewayServer(_problem(), policy=POLICY, gid=g, gateways=k,
                             cluster_dir=tmpdir,
                             visibility_timeout=visibility_timeout)
               for g in range(k)]
    for s in servers:
        s.start()
    return servers


def _durable_bytes(qs, ds) -> bytes:
    """The replay-equality observable, as canonical bytes: queue state with
    session-coupled wake counters masked (waiters/banked signals/wakeups are
    live-connection artifacts a replayed process cannot have), DataServer
    reduced to kv/models/latest (accounting counters move on read-only
    traffic, which is deliberately never op-logged)."""
    queues = durable_fingerprint(qs)
    for q in queues.values():
        q.pop("wakeups", None)
    dsnap = ds.snapshot()
    return encode_message({
        "queues": queues,
        "ds": {k: dsnap[k] for k in ("kind", "kv", "models", "latest")},
    })


# ---------------------------------------------------------------------------
# 1. op-log replay determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("snapshot_every", [0, 4])
def test_oplog_replay_bitmatches_live_state(snapshot_every):
    """Base + log replay == the live server's durable surface, bit-for-bit.
    ``snapshot_every=4`` rolls fresh base epochs mid-run, so the replay
    starts from an interior base and covers the epoch-truncation path too."""
    with tempfile.TemporaryDirectory() as td:
        server = GatewayServer(_problem(), policy=POLICY, cluster_dir=td,
                               snapshot_every=snapshot_every)
        server.start()
        try:
            tr = SocketTransport("127.0.0.1", server.port, "replay0")
            final, tasks = run_volunteer(tr, "replay0", N_VERSIONS,
                                         policy=POLICY)
            tr.close()
            assert (final, tasks) == (N_VERSIONS, N_TASKS)
            live = _durable_bytes(server.qs, server.ds)
        finally:
            server.close()
        prefix = os.path.join(td, "gw0.oplog")
        rq, rd, meta = replay_oplog(prefix, policy=POLICY)
        assert meta is not None and meta["policy"] == POLICY
        assert _durable_bytes(rq, rd) == live
        assert rd.latest_version == N_VERSIONS


def test_oplog_restore_bitmatches_snapshot_restore():
    """Booting a fresh gateway from the op log must land on the same durable
    state as booting from a full snapshot of the same run — the two recovery
    paths may never diverge."""
    with tempfile.TemporaryDirectory() as td:
        snap = os.path.join(td, "state.snap")
        server = GatewayServer(_problem(), policy=POLICY,
                               cluster_dir=os.path.join(td, "log"),
                               snapshot_path=snap)
        server.start()
        try:
            tr = SocketTransport("127.0.0.1", server.port, "boot0")
            run_volunteer(tr, "boot0", N_VERSIONS, policy=POLICY)
            tr.close()
            server.snapshot()
        finally:
            server.close()
        from_log = GatewayServer(
            _problem(), policy=POLICY,
            restore_from=os.path.join(td, "log", "gw0.oplog"))
        from_snap = GatewayServer(_problem(), policy=POLICY,
                                  restore_from=snap)
        assert _durable_bytes(from_log.qs, from_log.ds) == \
            _durable_bytes(from_snap.qs, from_snap.ds)
        # a finished run restores as finished on both paths
        assert from_log.done.is_set() and from_snap.done.is_set()


def test_replay_survives_torn_oplog_tail():
    """Crash-at-byte-k on the live log: truncating the final segment
    mid-record must still replay cleanly to a durable prefix (the torn op
    was never acknowledged as durable, so losing it is correct)."""
    with tempfile.TemporaryDirectory() as td:
        server = GatewayServer(_problem(), policy=POLICY, cluster_dir=td)
        server.start()
        try:
            tr = SocketTransport("127.0.0.1", server.port, "torn0")
            run_volunteer(tr, "torn0", N_VERSIONS, policy=POLICY)
            tr.close()
        finally:
            server.close()
        prefix = os.path.join(td, "gw0.oplog")
        log = OpLog(prefix)
        full = log.op_count()
        assert full > 0
        seg = log._seg_path(log.epoch, log.seg)
        size = os.path.getsize(seg)
        with open(seg, "r+b") as f:
            f.truncate(size - 5)              # tear the final record
        torn = OpLog(prefix)
        assert torn.op_count() == full - 1
        rq, rd, _ = replay_oplog(prefix, policy=POLICY)
        assert durable_fingerprint(rq)        # replays without raising
        assert 0 <= rd.latest_version <= N_VERSIONS


# ---------------------------------------------------------------------------
# 2./4. kill -9 failover: lease expiry across the handoff, peer adoption
# ---------------------------------------------------------------------------

def test_mid_handoff_lease_expiry_requeues_on_adopter():
    """A lease granted through the victim is mid-flight when the victim
    dies. The adopter replays the lease op (original deadline and all);
    the consumer never acks, so the adopter's sweeper must expire it and
    requeue the ticket — then a fresh volunteer finishes the run."""
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(2, td, visibility_timeout=1.0)
        try:
            # K=2: gw0 owns MODEL_KEY and every queue; gw1 pure-forwards
            assert servers[0].ring.owner_of(INITIAL_QUEUE) == 0
            holder = SocketTransport("127.0.0.1", servers[1].port, "holder")
            grant = holder.call(LeaseReq(INITIAL_QUEUE, "holder", 0.0))
            assert isinstance(grant, LeaseGrant)
            servers[0].die()                 # in-process kill -9 stand-in
            final, tasks, reconnects = run_volunteer_resilient(
                "127.0.0.1", servers[1].port, "finisher", N_VERSIONS,
                policy=POLICY, task_delay=0.0)
            assert final == N_VERSIONS
            # the abandoned lease expired on the ADOPTER and was re-done
            assert tasks == N_TASKS
            requeued = sum(q.requeued
                           for q in servers[1].qs.queues.values())
            assert requeued >= 1, "abandoned lease never expired"
            holder.close()
        finally:
            for s in servers:
                s.close()


def test_inprocess_die_is_adopted_by_peer():
    """``die()`` the model owner mid-run: the surviving gateway must record
    the adoption in its ring, serve the dead slice, and the volunteers
    (one homed on each gateway) must converge with ≥1 reconnect."""
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(2, td, visibility_timeout=2.0)
        try:
            ports = [s.port for s in servers]
            results = {}

            def drive(i, home):
                order = [ports[home]] + [p for j, p in enumerate(ports)
                                         if j != home]
                results[i] = run_volunteer_resilient(
                    "127.0.0.1", order[0], f"adopt{i}", N_VERSIONS,
                    policy=POLICY, task_delay=0.08,
                    fallback_ports=tuple(order[1:]))

            threads = [threading.Thread(target=drive, args=(i, i),
                                        daemon=True) for i in range(2)]
            for t in threads:
                t.start()
            time.sleep(0.4)                  # mid-run
            servers[0].die()
            for t in threads:
                t.join(timeout=60)
                assert not t.is_alive(), "volunteer deadlocked on failover"
            finals = [results[i][0] for i in sorted(results)]
            assert finals == [N_VERSIONS] * 2
            assert sum(results[i][2] for i in results) >= 1
            assert servers[1].ring.adoptions() == {0: 1}
            assert servers[1].ring.owner_of(MODEL_KEY) == 1
        finally:
            for s in servers:
                s.close()


def test_sigkilled_gateway_process_is_adopted_by_peer():
    """The real thing: 2 gateway PROCESSES, SIGKILL the model owner mid-run;
    the survivor replays the victim's op log from the shared cluster_dir and
    a volunteer failing over by port finishes at the reference version."""
    k = 2
    victim = GatewayRing(range(k)).owner_of(MODEL_KEY)
    with tempfile.TemporaryDirectory() as td:
        procs = [subprocess.Popen(
            [sys.executable, "-m", "repro.core.gateway", "--serve",
             "--gid", str(gid), "--gateways", str(k), "--cluster-dir", td,
             "--n-versions", str(N_VERSIONS), "--n-mb", str(N_MB),
             "--policy", POLICY, "--visibility-timeout", "2.0",
             "--timeout", "120"],
            env={**os.environ, "PYTHONPATH": "src"}) for gid in range(k)]
        try:
            ports = [_wait_port(os.path.join(td, f"gw{g}.port"), procs[g])
                     for g in range(k)]
            box = {}

            def drive():
                box["r"] = run_volunteer_resilient(
                    "127.0.0.1", ports[victim], "sig0", N_VERSIONS,
                    policy=POLICY, task_delay=0.1,
                    fallback_ports=tuple(p for g, p in enumerate(ports)
                                         if g != victim))

            t = threading.Thread(target=drive, daemon=True)
            t.start()
            time.sleep(0.4)
            assert procs[victim].poll() is None, "victim exited early"
            procs[victim].send_signal(signal.SIGKILL)
            procs[victim].wait(timeout=10)
            t.join(timeout=90)
            assert not t.is_alive(), "volunteer deadlocked after SIGKILL"
            final, tasks, reconnects = box["r"]
            assert final == N_VERSIONS
            assert reconnects >= 1, "the kill was never observed"
            # the survivor reaches the commit target and exits 0
            rcs = [procs[g].wait(timeout=60) for g in range(k)
                   if g != victim]
            assert rcs == [0]
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()


# ---------------------------------------------------------------------------
# 3. cross-gateway nack ordering
# ---------------------------------------------------------------------------

def test_cross_gateway_nack_front_preserves_fifo():
    """Lease through the NON-owning gateway (the op rides a ``Forward``),
    give the ticket back with ``front=True``: the very next lease must
    return the same body. ``front=False`` must rotate it to the back."""
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(2, td, visibility_timeout=30.0)
        try:
            assert INITIAL_QUEUE not in servers[1].qs.queues, \
                "gw1 must not own the task queue in a K=2 ring"
            tr = SocketTransport("127.0.0.1", servers[1].port, "nack0")
            g1 = tr.call(LeaseReq(INITIAL_QUEUE, "nack0", 0.0))
            assert isinstance(g1, LeaseGrant)
            tr.call(Nack(INITIAL_QUEUE, g1.tag, front=True))
            g2 = tr.call(LeaseReq(INITIAL_QUEUE, "nack0", 0.0))
            assert isinstance(g2, LeaseGrant)
            assert g2.body == g1.body, \
                "front=True nack lost its place across the Forward hop"
            # back-of-queue nack: with n_mb >= 2 tickets pending, the next
            # lease must be a DIFFERENT ticket
            tr.call(Nack(INITIAL_QUEUE, g2.tag, front=False))
            g3 = tr.call(LeaseReq(INITIAL_QUEUE, "nack0", 0.0))
            assert isinstance(g3, LeaseGrant)
            assert g3.body != g2.body, \
                "front=False nack failed to rotate to the back"
            tr.close()
        finally:
            for s in servers:
                s.close()


def test_forwarded_latestreq_answers_from_model_owner():
    """Sanity on the routing fabric the nack test rides: DataServer state
    lives only on the MODEL_KEY owner, yet a client of the other gateway
    sees it through the Forward path."""
    with tempfile.TemporaryDirectory() as td:
        servers = _cluster(2, td)
        try:
            tr = SocketTransport("127.0.0.1", servers[1].port, "lat0")
            reply = tr.call(LatestReq())
            assert reply.version == 0         # v0 enqueued, nothing trained
            tr.close()
        finally:
            for s in servers:
                s.close()


# ---------------------------------------------------------------------------
# 5. op-log segmentation properties
# ---------------------------------------------------------------------------

def _run_script(prefix: str, script, segment_ops: int):
    """Drive an OpLog through a base/append/reopen script; returns the model
    state: (final log, expected base bytes, expected op records in order)."""
    log = OpLog(prefix, segment_ops=segment_ops)
    base, ops = None, []
    for step in script:
        kind, payload = step
        if kind == "base":
            log.write_base(payload)
            base, ops = payload, []
        elif kind == "append":
            log.append(payload)
            ops.append(payload)
        elif kind == "reopen":
            # process restart: a fresh object must resume the same epoch
            # and segment counters from what is on disk
            log = OpLog(prefix, segment_ops=segment_ops)
    return log, base, ops


def _crash_survivors(log: OpLog, ops, crash_at: int):
    """Truncate the final segment file to ``crash_at`` bytes and return the
    records the torn log must still recover: every record in earlier
    segments plus the final segment's records whose framed extent
    (8-byte header + payload) fits inside the cut."""
    seg_path = log._seg_path(log.epoch, log.seg)
    if not os.path.exists(seg_path):
        return ops                            # nothing appended this epoch
    size = os.path.getsize(seg_path)
    crash_at = min(crash_at, size)
    in_last = log._ops_in_seg
    head, tail = ops[:len(ops) - in_last], ops[len(ops) - in_last:]
    with open(seg_path, "r+b") as f:
        f.truncate(crash_at)
    survivors, cum = [], 0
    for rec in tail:
        cum += 8 + len(rec)
        if cum > crash_at:
            break
        survivors.append(rec)
    return head + survivors


def _check_script(tmp: str, script, segment_ops: int, crash_at=None):
    """The property: after any script (+ optional crash), ``load()`` returns
    exactly the newest complete base and the acknowledged-durable prefix."""
    prefix = os.path.join(tmp, "prop.oplog")
    log, base, ops = _run_script(prefix, script, segment_ops)
    expected = ops if crash_at is None \
        else _crash_survivors(log, ops, crash_at)
    got_base, got_ops = OpLog(prefix).load()
    assert got_base == base
    assert got_ops == expected
    # durability is monotone: the recovered ops are a PREFIX, never a gap
    assert ops[:len(got_ops)] == got_ops


def _random_script(rng: random.Random, n_steps: int):
    script, serial = [], 0
    for _ in range(n_steps):
        roll = rng.random()
        if roll < 0.15:
            script.append(("base", b"B%d" % serial * rng.randint(1, 40)))
        elif roll < 0.25:
            script.append(("reopen", None))
        else:
            script.append(
                ("append", b"op%d:" % serial + bytes(rng.randint(0, 60))))
        serial += 1
    return script


@pytest.mark.parametrize("seed", range(8))
def test_oplog_random_interleavings_recover_durable_prefix(seed):
    """Seeded port of the hypothesis property (runs whether or not
    hypothesis is installed): random base/append/reopen interleavings with
    tiny segments, crashed at a random byte offset, always recover the
    newest base + a contiguous acknowledged prefix."""
    rng = random.Random(seed)
    script = _random_script(rng, rng.randint(5, 40))
    with tempfile.TemporaryDirectory() as tmp:
        _check_script(tmp, script, segment_ops=rng.randint(1, 5),
                      crash_at=rng.randint(0, 2000))


@pytest.mark.parametrize("seed", range(4))
def test_oplog_random_interleavings_intact(seed):
    rng = random.Random(1000 + seed)
    script = _random_script(rng, rng.randint(5, 40))
    with tempfile.TemporaryDirectory() as tmp:
        _check_script(tmp, script, segment_ops=rng.randint(1, 5))


def test_oplog_segment_roll_boundaries_exact():
    """Deterministic corner: segment_ops=2 with 5 appends lands records in
    segments [2, 2, 1]; a crash cutting exactly on a record boundary keeps
    everything before the cut and nothing after."""
    with tempfile.TemporaryDirectory() as tmp:
        prefix = os.path.join(tmp, "b.oplog")
        log = OpLog(prefix, segment_ops=2)
        log.write_base(b"base")
        recs = [b"r%d" % i for i in range(5)]
        for r in recs:
            log.append(r)
        assert (log.seg, log._ops_in_seg) == (2, 1)
        base, ops = OpLog(prefix).load()
        assert (base, ops) == (b"base", recs)
        # cut the LAST segment exactly after its only record: lossless
        seg = log._seg_path(log.epoch, 2)
        with open(seg, "r+b") as f:
            f.truncate(8 + len(recs[4]))
        assert OpLog(prefix).load() == (b"base", recs)
        # cut one byte into the record header: the record is torn
        with open(seg, "r+b") as f:
            f.truncate(1)
        assert OpLog(prefix).load() == (b"base", recs[:4])


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _step = st.one_of(
        st.tuples(st.just("append"), st.binary(min_size=0, max_size=80)),
        st.tuples(st.just("base"), st.binary(min_size=1, max_size=80)),
        st.tuples(st.just("reopen"), st.none()),
    )

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(script=st.lists(_step, min_size=1, max_size=30),
           segment_ops=st.integers(min_value=1, max_value=6),
           crash_at=st.integers(min_value=0, max_value=3000))
    def test_oplog_property_hypothesis(script, segment_ops, crash_at):
        with tempfile.TemporaryDirectory() as tmp:
            _check_script(tmp, list(script), segment_ops, crash_at=crash_at)
else:
    @pytest.mark.skip(reason="hypothesis not installed; seeded ports above "
                             "cover the same property")
    def test_oplog_property_hypothesis():
        pass


# ---------------------------------------------------------------------------
# chaos tier: the gateway_kill journal drill
# ---------------------------------------------------------------------------

def test_chaos_gateway_kill_replays_journal_and_converges():
    sim = ChaosSimulator(_smoke_problem(), _smoke_specs(),
                         schedule=gateway_schedule(0), mode="event",
                         cost=_smoke_cost(), policy=POLICY)
    result = sim.run()
    assert sim.gateway_kills >= 1
    assert sim.journal_ops_replayed > 0
    assert result.final_version == _smoke_problem().n_versions


def test_chaos_gateway_kill_is_invisible_vs_expire_reference():
    """Substituting every gateway_kill with a plain expire sweep must yield
    a bit-identical SimResult: the journal replay + snapshot round-trip may
    not perturb the run in any observable way."""
    schedule = gateway_schedule(1)
    ref = ChaosSchedule(
        [ChaosEvent(e.t, "expire") if e.kind == "gateway_kill" else e
         for e in schedule.events],
        seed=1, label="gateway-ref-1")
    killed = run_chaos(_smoke_problem(), _smoke_specs(), schedule,
                       mode="event", cost=_smoke_cost(), policy=POLICY)
    ticked = run_chaos(_smoke_problem(), _smoke_specs(), ref,
                       mode="event", cost=_smoke_cost(), policy=POLICY)
    assert killed == ticked


# ---------------------------------------------------------------------------
# bench guard: one perf series, one suite file
# ---------------------------------------------------------------------------

def _bench_file(tmp, stem, names):
    path = tmp / f"BENCH_{stem}.json"
    path.write_text(json.dumps(
        [{"name": n, "params": {}, "makespan": 1.0, "events": 1,
          "bytes": None} for n in names]))
    return path


def test_bench_check_rejects_cross_file_duplicate_names(tmp_path, capsys):
    a = _bench_file(tmp_path, "alpha", ["alpha_x", "alpha_y"])
    b = _bench_file(tmp_path, "alpha_x", ["alpha_x"])
    problems = check_bench_records([a, b])
    assert problems == 1
    assert "already used" in capsys.readouterr().out


def test_bench_check_accepts_disjoint_names(tmp_path):
    a = _bench_file(tmp_path, "alpha", ["alpha_x", "alpha_y"])
    b = _bench_file(tmp_path, "beta", ["beta_x"])
    assert check_bench_records([a, b]) == 0


def test_bench_check_duplicate_within_one_file_is_legal(tmp_path):
    """Param rows share a series name WITHIN a suite file by design; only
    cross-file reuse makes the trajectory ambiguous."""
    a = _bench_file(tmp_path, "alpha", ["alpha_x", "alpha_x"])
    assert check_bench_records([a]) == 0
