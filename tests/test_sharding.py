"""Sharding-policy unit tests: every emitted PartitionSpec must tile its
tensor exactly (divisibility), TP lands on the intended dims, FSDP falls
back gracefully, and decode caches follow the DESIGN §5 rules.

These run on 1 CPU device — specs are pure metadata, no mesh needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.distributed import sharding as SH
from repro.models import model as M

SINGLE = SH.ShardingPolicy(("data", "model"), (16, 16))
MULTI = SH.ShardingPolicy(("pod", "data", "model"), (2, 16, 16))


def _axis_size(policy, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([policy.size(a) for a in entry]))
    return policy.size(entry)


def _check_divisible(specs, shapes, policy):
    flat_s, _ = jax.tree_util.tree_flatten(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for d, entry in enumerate(spec):
            size = _axis_size(policy, entry)
            assert leaf.shape[d] % size == 0, (leaf.shape, spec, d)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
@pytest.mark.parametrize("policy", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_param_specs_divisible(arch, policy):
    cfg = C.get(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, policy)
    _check_divisible(specs, shapes, policy)


def test_tp_lands_on_heads_for_wide_archs():
    cfg = C.get("qwen1.5-110b")   # 64 heads: divisible by model=16
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, SINGLE)
    wq = specs["blocks"]["l0"]["attn"]["wq"]     # [U, D, H, hd]
    assert wq[2] == "model"
    wo_mlp = specs["blocks"]["l0"]["mlp"]["wo"]  # [U, F, D]
    assert wo_mlp[1] == "model"


def test_tp_falls_back_to_head_dim_for_narrow_heads():
    cfg = C.get("whisper-base")   # 8 heads < 16 -> hd=64 gets the TP axis
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, SINGLE)
    wq = specs["decoder"]["l0"]["attn"]["wq"]
    assert wq[2] is None and wq[3] == "model"


def test_expert_dim_gets_model_axis():
    cfg = C.get("arctic-480b")    # 128 experts / 16 = 8 per device
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, SINGLE)
    wi = specs["blocks"]["l0"]["moe"]["experts"]["wi"]   # [U, E, D, F]
    assert wi[1] == "model"


def test_small_leaves_stay_replicated():
    cfg = C.get("stablelm-1.6b")
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, SINGLE)
    norm = specs["blocks"]["l0"]["norm1"]["scale"]       # [U, D] small
    assert all(e is None for e in norm)


def test_stacked_unit_dim_never_sharded():
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = SH.param_specs(shapes, MULTI)
        for root in ("blocks", "encoder", "decoder"):
            if root not in specs:
                continue
            for spec in jax.tree.leaves(specs[root],
                                        is_leaf=lambda x: isinstance(x, P)):
                if len(spec) > 0:
                    assert spec[0] is None, (root, spec)


def test_batch_specs():
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 4097), jnp.int32)}
    specs = SH.batch_specs(shapes, MULTI)
    assert specs["tokens"] == P(("pod", "data"), None)
    # indivisible batch -> replicated
    shapes1 = {"tokens": jax.ShapeDtypeStruct((1, 4097), jnp.int32)}
    specs1 = SH.batch_specs(shapes1, MULTI)
    assert specs1["tokens"] == P(None, None)


def test_cache_specs_decode32k_vs_long500k():
    cfg = C.get("qwen1.5-110b")
    # decode_32k: batch 128 divisible -> batch on data, seq on model
    cshape = jax.eval_shape(lambda: M.init_cache(cfg, 128, 32768))
    specs = SH.cache_specs(cshape, SINGLE)
    k = specs["l0"]["k"]
    assert k == P(None, ("data",), "model", None, None) or \
        k == P(None, "data", "model", None, None)
    # long_500k: batch 1 -> the sequence dim takes every axis
    cshape1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, 524288))
    specs1 = SH.cache_specs(cshape1, SINGLE)
    k1 = specs1["l0"]["k"]
    assert k1[2] == ("data", "model")


def test_ssm_cache_specs():
    cfg = C.get("falcon-mamba-7b")
    cshape = jax.eval_shape(lambda: M.init_cache(cfg, 128, 16))
    specs = SH.cache_specs(cshape, SINGLE)
    h = specs["l0"]["h"]        # [U, B, Di, N]
    assert h[1] in ("data", ("data",)) and h[2] == "model"
    conv = specs["l0"]["conv"]  # [U, B, K-1, Di]
    assert conv[3] == "model"


def test_opt_state_specs_mirror_params():
    cfg = C.get_smoke("stablelm-1.6b")
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = SH.param_specs(shapes, SINGLE)
    from repro.optim import rmsprop
    opt = rmsprop(0.1)
    ostate = jax.eval_shape(lambda: opt.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)))
    ospecs = SH.opt_state_specs(ostate, pspecs)
    assert ospecs["step"] == P()
    assert ospecs["ms"] == pspecs


def test_hd_fallback_off_replicates_qkv():
    cfg = C.get("internvl2-1b")   # 14 heads: indivisible by 16
    pol = SH.ShardingPolicy(("data", "model"), (16, 16),
                            attn_hd_fallback=False)
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, pol)
    wq = specs["blocks"]["l0"]["attn"]["wq"]
    assert wq[2] is None and wq[3] is None     # no head_dim sharding


def test_padded_vocab_shards_on_model():
    cfg = C.get("internvl2-1b").replace(vocab_pad_to=256)
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = SH.param_specs(shapes, SINGLE)
    assert specs["embed"][0] == "model"        # vocab-TP now possible
