"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Din,H", [(1, 7, 5), (8, 96, 50), (16, 128, 128),
                                     (5, 33, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_sweep(B, Din, H, dtype):
    k = jax.random.PRNGKey(B * 1000 + Din)
    ks = jax.random.split(k, 5)
    x = jax.random.normal(ks[0], (B, Din), dtype)
    h = jax.random.normal(ks[1], (B, H), dtype)
    c = jax.random.normal(ks[2], (B, H), dtype)
    W = (jax.random.normal(ks[3], (Din + H, 4 * H)) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[4], (4 * H,)) * 0.1).astype(dtype)
    h1, c1 = ops.lstm_cell(x, h, c, W, b, interpret=True)
    h2, c2 = ref.lstm_cell(x, h, c, W, b)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(4, 64), (2, 17, 256), (1, 3, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
    y1 = ops.rmsnorm(x, s, interpret=True)
    y2 = ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,Kv,hd", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 129, 8, 4, 64),     # GQA, ragged seq
    (1, 200, 8, 1, 16),     # MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 37), (False, 0)])
def test_flash_attention_sweep(B, S, H, Kv, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, Kv, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, Kv, hd)) * 0.5
    o1 = ops.flash_attention(q, k, v, causal=causal, window=window,
                             blk_q=64, blk_k=64, interpret=True)
    o2 = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=5e-5, atol=5e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = (jax.random.normal(ks[0], (2, 64, 4, 32)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (2, 64, 2, 32)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (2, 64, 2, 32)) * 0.5).astype(dtype)
    o1 = ops.flash_attention(q, k, v, interpret=True)
    o2 = ref.flash_attention(q, k, v)
    assert o1.dtype == dtype
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n", [4, 128, 4096, 10000])
def test_ternary_kernel_roundtrip(n):
    n4 = (n + 3) // 4 * 4
    g = jax.random.normal(jax.random.PRNGKey(n), (n4,))
    s = jnp.max(jnp.abs(g))
    packed = ops.ternary_encode(g, s, interpret=True)
    assert packed.dtype == jnp.uint8 and packed.shape == (n4 // 4,)
    dec = ops.ternary_decode(packed, s, interpret=True)
    t = ref.ternary_encode(g, s).astype(jnp.float32) * s
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(t))
    # ref-level pack/unpack agrees with the kernel bytes
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(ref.ternary_pack(ref.ternary_encode(g, s))))


def test_lstm_model_pallas_path_matches_jnp():
    """The full paper model with use_pallas=True equals the jnp path."""
    from repro.models import lstm as LSTM
    import repro.configs as C
    cfg = C.get("paper-lstm").replace(vocab=64)
    params = LSTM.init_lstm_model(jax.random.PRNGKey(0), cfg, 64)
    x = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0, 64), 64)
    batch = {"x": x, "y": jnp.zeros((4,), jnp.int32)}
    l1 = LSTM.lstm_loss(params, batch, use_pallas=False)
    l2 = LSTM.lstm_loss(params, batch, use_pallas=True, interpret=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
