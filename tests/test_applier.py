"""Batched real-JAX server applier: bit-exactness of the drained fast path,
drain-edge semantics (rejection ordering, gc interplay, empty drains),
measured publish sizes, lazy blob materialization, and the simulator's
dispatch-cost pipeline.

The load-bearing contract: ``submit_batch`` over a ``make_real_applier``
must land on the SAME BITS as ``sequential_async`` / chained ``apply_one``
for every drain split and both applier modes — batching is a pure latency
optimization, invisible in replies and in model bytes.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.paper_lstm import TrainParams
from repro.core.aggregation import make_policy
from repro.core.applier import LazyModelBlob, RealApplier, make_real_applier
from repro.core.dataserver import DataServer
from repro.core.mapreduce import (TrainingProblem, sequential_async,
                                  sequential_local)
from repro.core.protocol import (FetchModel, ModelBlob, ServerEndpoint,
                                 SubmitUpdate, UpdateCommitted,
                                 UpdateRejected, wire_size)
from repro.core.queue import QueueServer
from repro.core.simulator import (CostModel, Simulator, SyntheticProblem,
                                  VolunteerSpec)
from repro.core.tasks import DeltaResult, GradResult, INITIAL_QUEUE
from repro.data.text import synthetic_corpus

N = 12  # updates per staged chain — enough for multi-segment drains


@pytest.fixture(scope="module")
def problem():
    tp = TrainParams(batch_size=32, examples_per_epoch=256, num_epochs=1,
                     sample_len=40, mini_batch_size=8,
                     mini_batches_to_accumulate=4)
    return TrainingProblem.paper_problem(corpus=synthetic_corpus(20_000),
                                         tp=tp, seed=0, d_model=8)


@pytest.fixture(scope="module")
def grads(problem):
    """g_i computed at params_i along the reference chain, as numpy (the
    wire-deserialized form the server actually sees)."""
    p, s = problem.params0, problem.opt_state0
    out = []
    for i in range(N):
        v, mb = problem.stream_slot(i)
        g, _ = problem.map_compute(p, v, mb)
        out.append(jax.tree.map(np.asarray, g))
        p, s = problem.apply_one(p, s, g)
    return out


@pytest.fixture(scope="module")
def ref(problem):
    p, s, _ = sequential_async(problem, n_updates=N)
    return p, s


def bit_eq(a, b) -> bool:
    return bool(jax.tree.all(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def fresh_endpoint(problem, *, batch, policy="staleness:2", gc_keep=None):
    qs, ds = QueueServer(), DataServer()
    qs.declare(INITIAL_QUEUE, timeout=float("inf"))
    ds.publish_model(0, (problem.params0, problem.opt_state0), nbytes=0)
    applier = make_real_applier(problem, make_policy(policy), batch=batch,
                                gc_keep=gc_keep)
    return ServerEndpoint(qs, ds, applier=applier), qs, ds, applier


def submit(endpoint, qs, results, *, split):
    """Drive ``results`` through ``submit_batch`` in drains of the given
    sizes, leasing a real ticket per message."""
    replies = []
    it = iter(results)
    for size in split:
        msgs = []
        for r in (next(it) for _ in range(size)):
            qs.publish(INITIAL_QUEUE, "t")
            tag, _ = qs.lease(INITIAL_QUEUE, "w", 0.0)
            msgs.append(SubmitUpdate(INITIAL_QUEUE, tag, r))
        replies.extend(endpoint.submit_batch(msgs))
    return replies


def grad_results(grads):
    return [GradResult(version=i, mb_index=0, payload=g, computed_at=i)
            for i, g in enumerate(grads)]


# ---------------------------------------------------------------------------
# bit-exactness matrix: drain splits x applier modes == sequential_async
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("split", [[1] * N, [4] * (N // 4), [N],
                                   [1, 2, 3, 6], [5, 7]],
                         ids=["ones", "fours", "whole", "ragged", "two"])
@pytest.mark.parametrize("batch", [False, True], ids=["plain", "batched"])
def test_drained_grads_bit_match_sequential(problem, grads, ref, split,
                                            batch):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch)
    replies = submit(endpoint, qs, grad_results(grads), split=split)
    assert [r.version for r in replies] == list(range(1, N + 1))
    assert all(isinstance(r, UpdateCommitted) for r in replies)
    blob = endpoint.handle(FetchModel(N)).blob
    assert bit_eq(blob, ref)
    assert ap.applied == N and ap.rejected == 0
    if batch:
        expect = sum(1 for s in split if s >= 2)
        assert ap.batches == expect
        assert ap.batched_updates == sum(s for s in split if s >= 2)
    else:
        assert ap.batches == 0 and ap.batched_updates == 0


def test_intermediate_versions_bit_match_sequential(problem, grads):
    """EVERY published version — not just the last — matches the reference
    prefix chain, whichever drain split produced it."""
    p, s = problem.params0, problem.opt_state0
    prefixes = []
    for g in grads[:6]:
        p, s = problem.apply_one(p, s, g)
        prefixes.append((p, s))
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True)
    submit(endpoint, qs, grad_results(grads[:6]), split=[2, 4])
    for v in range(1, 7):
        assert bit_eq(endpoint.handle(FetchModel(v)).blob, prefixes[v - 1])


def test_delta_chain_bit_matches_sequential_local(problem):
    k, n_rounds = 4, 4
    refp, refs, _ = sequential_local(problem, k=k, n_updates=n_rounds)
    p, s = problem.params0, problem.opt_state0
    deltas = []
    for slot in range(n_rounds):
        d, _ = problem.local_compute(p, s, slot * k, k)
        deltas.append(jax.tree.map(np.asarray, d))
        p, s = problem.apply_delta(p, s, d)
    results = [DeltaResult(slot=i, computed_at=i, payload=d)
               for i, d in enumerate(deltas)]
    for batch in (False, True):
        endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch)
        submit(endpoint, qs, results, split=[n_rounds])
        assert bit_eq(endpoint.handle(FetchModel(n_rounds)).blob,
                      (refp, refs))


def test_mixed_grad_delta_drain_segments(problem, grads):
    """A drain mixing result kinds splits into homogeneous segments; only
    the grad segment (>= 2 elements) rides the batched dispatch, and the
    result bit-matches the fully sequential chain."""
    p, s = problem.params0, problem.opt_state0
    for g in grads[:3]:
        p, s = problem.apply_one(p, s, g)
    d, _ = problem.local_compute(p, s, 0, 2)
    p_ref, s_ref = problem.apply_delta(p, s, d)
    results = grad_results(grads[:3]) + [
        DeltaResult(slot=0, computed_at=3, payload=jax.tree.map(np.asarray, d))]
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True)
    replies = submit(endpoint, qs, results, split=[4])
    assert all(isinstance(r, UpdateCommitted) for r in replies)
    assert bit_eq(endpoint.handle(FetchModel(4)).blob, (p_ref, s_ref))
    assert ap.batches == 1 and ap.batched_updates == 3  # grads only


# ---------------------------------------------------------------------------
# drain-edge semantics
# ---------------------------------------------------------------------------

def test_rejection_mid_drain_nacks_front_in_order(problem, grads):
    """Element i is admitted against the version it would have observed
    sequentially; a rejected element reports that version, its ticket goes
    back to the FRONT of the queue, and later elements still commit."""
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True,
                                          policy="staleness:0")
    results = grad_results(grads[:4])
    # stale second element: computed_at=0 but it would apply onto v1
    results[1] = dataclasses.replace(results[1], computed_at=0)
    results[0] = dataclasses.replace(results[0], computed_at=0)
    results[2] = dataclasses.replace(results[2], computed_at=1)
    results[3] = dataclasses.replace(results[3], computed_at=2)
    replies = submit(endpoint, qs, results, split=[4])
    assert isinstance(replies[0], UpdateCommitted) and replies[0].version == 1
    assert isinstance(replies[1], UpdateRejected) and replies[1].latest == 1
    assert isinstance(replies[2], UpdateCommitted) and replies[2].version == 2
    assert isinstance(replies[3], UpdateCommitted) and replies[3].version == 3
    assert ap.applied == 3 and ap.rejected == 1
    # the nacked ticket is back at the front, ahead of anything later
    qs.publish(INITIAL_QUEUE, "later")
    tag, body = qs.lease(INITIAL_QUEUE, "w2", 0.0)
    assert body == "t"
    # and the committed chain is still the exact sequential one (the stale
    # gradient was dropped, not misapplied)
    p, s = problem.params0, problem.opt_state0
    for g in (grads[0], grads[2], grads[3]):
        p, s = problem.apply_one(p, s, g)
    assert bit_eq(endpoint.handle(FetchModel(3)).blob, (p, s))


def test_all_rejected_drain_publishes_nothing(problem, grads):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True,
                                          policy="staleness:0")
    # advance to v1 so computed_at=0 submissions are all stale
    submit(endpoint, qs, grad_results(grads[:1]), split=[1])
    writes_before, latest_before = ds.writes, ds.latest_version
    stale = [dataclasses.replace(r, computed_at=0)
             for r in grad_results(grads[1:4])]
    replies = submit(endpoint, qs, stale, split=[3])
    assert all(isinstance(r, UpdateRejected) for r in replies)
    assert all(r.latest == 1 for r in replies)
    assert ds.writes == writes_before and ds.latest_version == latest_before
    assert ap.applied == 1 and ap.rejected == 3
    assert ap.batches == 0  # no admitted run, no dispatch


def test_empty_drain_is_a_noop(problem):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True)
    writes_before = ds.writes
    assert endpoint.submit_batch([]) == []
    assert ds.writes == writes_before and ap.applied == 0


def test_gc_keep_prunes_same_survivors_as_sequential(problem, grads):
    """gc runs ONCE at drain end; the surviving version set must equal the
    sequential (gc-after-every-publish) endpoint's, and the kept blobs must
    be fetchable (a drain must never publish an already-donated buffer)."""
    survivors = {}
    for batch, split in ((False, [1] * 6), (True, [6])):
        endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch,
                                              gc_keep=2)
        submit(endpoint, qs, grad_results(grads[:6]), split=split)
        survivors[batch] = sorted(ds._models)
        for v in survivors[batch]:
            blob = endpoint.handle(FetchModel(v)).blob
            jax.block_until_ready(jax.tree.leaves(blob))
    assert survivors[False] == survivors[True] == [5, 6]


def test_gc_keep_across_multiple_drains(problem, grads):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True, gc_keep=3)
    submit(endpoint, qs, grad_results(grads[:8]), split=[4, 4])
    assert sorted(ds._models) == [6, 7, 8]


# ---------------------------------------------------------------------------
# measured publish sizes (satellite: model_nbytes measured on each publish)
# ---------------------------------------------------------------------------

def test_model_nbytes_measured_matches_wire_encoding(problem, grads, ref):
    for batch in (False, True):
        endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch)
        assert ap.model_nbytes == 0  # nothing measured yet
        bytes_before = ds.bytes_written
        submit(endpoint, qs, grad_results(grads[:4]), split=[4])
        blob = endpoint.handle(FetchModel(4)).blob
        expect = wire_size(ModelBlob(0, True, blob))
        assert ap.model_nbytes == expect > 0
        # every one of the 4 publishes was accounted at the measured size
        assert ds.bytes_written - bytes_before == 4 * expect


def test_measured_nbytes_identical_across_modes(problem, grads):
    sizes = []
    for batch in (False, True):
        endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch)
        submit(endpoint, qs, grad_results(grads[:2]), split=[2])
        sizes.append(ap.model_nbytes)
    assert sizes[0] == sizes[1]


# ---------------------------------------------------------------------------
# lazy blob materialization
# ---------------------------------------------------------------------------

def test_batched_publishes_are_lazy_and_fetch_materializes(problem, grads):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True)
    submit(endpoint, qs, grad_results(grads[:4]), split=[4])
    stored = [ds._models[v] for v in (2, 3)]
    assert all(isinstance(b, LazyModelBlob) for b in stored)
    reply = endpoint.handle(FetchModel(3))
    assert not isinstance(reply.blob, LazyModelBlob)
    p, s = reply.blob
    assert jax.tree.leaves(p)  # a real params pytree


def test_snapshot_solidifies_lazy_blobs(problem, grads):
    endpoint, qs, ds, ap = fresh_endpoint(problem, batch=True)
    submit(endpoint, qs, grad_results(grads[:3]), split=[3])
    snap = ds.snapshot()
    for v, blob in snap["models"]:
        assert not isinstance(blob, LazyModelBlob)
    ds2 = DataServer()
    ds2.restore(snap)
    assert ds2.latest_version == 3


def test_reseed_restores_applier_state(problem, grads, ref):
    """Snapshot-restore path: reseeding from the stored latest blob lets the
    applier continue the chain bit-exactly."""
    for batch in (False, True):
        endpoint, qs, ds, ap = fresh_endpoint(problem, batch=batch)
        submit(endpoint, qs, grad_results(grads[:6]), split=[3, 3])
        backend2 = RealApplier(problem, batch=batch)
        backend2.reseed(ds.get_model(6), 6)
        blobs = backend2._advance(
            [GradResult(version=6 + i, mb_index=0, payload=g,
                        computed_at=6 + i)
             for i, g in enumerate(grads[6:])], 6)
        last = blobs[-1]
        last = last.materialize() if isinstance(last, LazyModelBlob) else last
        assert bit_eq(last, ref)


# ---------------------------------------------------------------------------
# flat-batch kernel: donation + packing + step unflatten
# ---------------------------------------------------------------------------

def test_apply_batch_matches_chained_apply_one(problem, grads):
    p, s = problem.params0, problem.opt_state0
    outs = problem.apply_batch(p, s, grads[:5])
    assert len(outs) == 5
    for i in range(5):
        p, s = problem.apply_one(p, s, grads[i])
        assert bit_eq(outs[i], (p, s))


def test_donated_apply_one_matches_plain(problem, grads):
    p0, s0 = problem.params0, problem.opt_state0
    plain = problem.apply_one(p0, s0, grads[0])
    # donate from an owned copy (donating problem.params0 would destroy it)
    own = jax.tree.map(lambda x: x + 0, (p0, s0))
    donated = problem.apply_one(own[0], own[1], grads[0], donate=True)
    assert bit_eq(plain, donated)


def test_pack_grad_rows_matches_per_row_pack(problem, grads):
    rows = problem.pack_grad_rows(grads[:5])
    expect = np.stack([problem.pack_grads(g) for g in grads[:5]])
    assert rows.shape == expect.shape
    assert np.array_equal(rows, expect)


def test_unflatten_step_matches_eager_slice(problem, grads):
    carry = problem.flat_carry(problem.params0, problem.opt_state0)
    rows = problem.pack_grad_rows(grads[:4])
    _, steps = problem.apply_batch_flat(carry, rows, donate=False)
    fp_s, vec_s, scal_s = steps
    for i in (0, 3):
        eager = problem.unflatten_carry(
            (fp_s[i], {k: v[i] for k, v in vec_s.items()},
             {k: v[i] for k, v in scal_s.items()}))
        assert bit_eq(problem.unflatten_step(steps, i), eager)


def test_flat_carry_round_trips(problem):
    carry = problem.flat_carry(problem.params0, problem.opt_state0)
    p, s = problem.unflatten_carry(carry)
    assert bit_eq((p, s), (problem.params0, problem.opt_state0))


def test_supports_flat_apply_gates_batch_mode(problem):
    assert problem.supports_flat_apply
    assert make_real_applier(problem, make_policy("staleness:2"),
                             batch=True).backend.batch is True
    off = make_real_applier(problem, make_policy("staleness:2"), batch=False)
    assert off.backend.batch is False and off.apply_batch is None


def test_applier_refuses_version_skew(problem, grads):
    backend = RealApplier(problem, batch=True)
    with pytest.raises(ValueError, match="only writer"):
        backend._advance(grad_results(grads[:2]), 5)


# ---------------------------------------------------------------------------
# simulator dispatch-cost pipeline
# ---------------------------------------------------------------------------

def _sim(server_apply, dispatch_cost=0.0, k=3):
    problem = SyntheticProblem(n_versions=4, n_mb=6, model_bytes=1.0e6,
                               grad_bytes=1.0e5)
    specs = [VolunteerSpec(f"v{i}", speed=1.0 + 0.1 * i) for i in range(k)]
    cost = CostModel(dispatch_cost=dispatch_cost)
    return Simulator(problem, specs, cost=cost, policy="staleness:2",
                     server_apply=server_apply)


def test_zero_dispatch_cost_is_bit_identical():
    """dispatch_cost=0.0 (the default) must leave server-applied runs
    untouched — same result dataclass, no dispatch accounting."""
    base = _sim(True).run()
    sim = _sim(True, dispatch_cost=0.0)
    again = sim.run()
    assert dataclasses.asdict(base) == dataclasses.asdict(again)
    assert sim.apply_dispatches == 0 and sim.batched_dispatch_credits == 0


def test_positive_dispatch_cost_pools_commits():
    """With a serial dispatch cost, concurrent arrivals pool into pending
    dispatches (batched credits) and the makespan stretches, but the run
    still completes every update."""
    sim = _sim(True, dispatch_cost=0.05, k=6)
    res = sim.run()
    assert res.final_version == 24
    assert sim.apply_dispatches > 0
    assert sim.batched_dispatch_credits > 0
    assert res.makespan > _sim(True, k=6).run().makespan
